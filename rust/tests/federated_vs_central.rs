//! End-to-end correctness: the EFMVFL trainer must reproduce centralized
//! gradient descent (the protocol is lossless up to fixed-point noise).

use efmvfl::coordinator::{train, TrainConfig};
use efmvfl::data::{split_vertical, synthetic};
use efmvfl::glm::{train_central, GlmKind};
use efmvfl::linalg;
use efmvfl::metrics;
use efmvfl::protocols::CpSelection;

fn lr_config() -> TrainConfig {
    TrainConfig::logistic(2)
        .with_key_bits(256)
        .with_iterations(8)
        .with_batch(None)
        .with_seed(11)
}

#[test]
fn lr_two_party_matches_central() {
    let mut data = synthetic::blobs(300, 1);
    data.standardize();
    let split = split_vertical(&data, 2);

    let rep = train(&split, &lr_config()).expect("train");
    let central = train_central(&data.x, &data.y, GlmKind::Logistic, 0.15, 8);

    // weight trajectories agree to fixed-point noise
    let fed_w = rep.full_weights();
    for (a, b) in fed_w.iter().zip(&central.weights) {
        assert!((a - b).abs() < 1e-2, "weights diverged: {a} vs {b}");
    }
    // loss curves agree (federated reports the Taylor loss; on blobs the
    // early iterations stay in the small-|wx| regime where they match)
    for (i, (lf, lc)) in rep.losses.iter().zip(&central.losses).enumerate() {
        assert!((lf - lc).abs() < 0.05, "iter {i}: {lf} vs {lc}");
    }
    assert_eq!(rep.iterations_run, 8);
    assert!(rep.comm_mb > 0.0);
}

#[test]
fn lr_three_party_matches_central() {
    let mut data = synthetic::credit_default_like(400, 12, 2);
    data.standardize();
    let split = split_vertical(&data, 3);

    let rep = train(&split, &lr_config()).expect("train");
    let central = train_central(&data.x, &data.y, GlmKind::Logistic, 0.15, 8);

    let fed_w = rep.full_weights();
    assert_eq!(fed_w.len(), central.weights.len());
    for (a, b) in fed_w.iter().zip(&central.weights) {
        assert!((a - b).abs() < 1e-2, "weights diverged: {a} vs {b}");
    }
}

#[test]
fn pr_two_party_matches_central() {
    let mut data = synthetic::dvisits_like(400, 10, 3);
    data.standardize();
    let split = split_vertical(&data, 2);

    let cfg = TrainConfig::poisson(2)
        .with_key_bits(256)
        .with_iterations(8)
        .with_batch(None)
        .with_seed(12);
    let rep = train(&split, &cfg).expect("train");
    let central = train_central(&data.x, &data.y, GlmKind::Poisson, 0.1, 8);

    for (a, b) in rep.full_weights().iter().zip(&central.weights) {
        assert!((a - b).abs() < 2e-2, "weights diverged: {a} vs {b}");
    }
    for (i, (lf, lc)) in rep.losses.iter().zip(&central.losses).enumerate() {
        assert!((lf - lc).abs() < 0.05, "iter {i}: {lf} vs {lc}");
    }
}

#[test]
fn gamma_two_party_matches_central() {
    // the paper's "other GLMs" claim (§4.2): Gamma regression with the
    // same four protocols, shares of e^{−WX} instead of e^{WX}
    let mut data = synthetic::claims_severity_like(400, 8, 13);
    data.standardize();
    let split = split_vertical(&data, 2);
    let mut cfg = lr_config().with_seed(13);
    cfg.kind = GlmKind::Gamma;
    cfg.learning_rate = 0.1;
    let rep = train(&split, &cfg).expect("train");
    let central = train_central(&data.x, &data.y, GlmKind::Gamma, 0.1, 8);
    for (a, b) in rep.full_weights().iter().zip(&central.weights) {
        assert!((a - b).abs() < 2e-2, "weights diverged: {a} vs {b}");
    }
    for (lf, lc) in rep.losses.iter().zip(&central.losses) {
        assert!((lf - lc).abs() < 0.05, "loss: {lf} vs {lc}");
    }
}

#[test]
fn tweedie_three_party_matches_central() {
    let mut data = synthetic::claims_severity_like(300, 9, 14);
    data.standardize();
    // zero-inflate ~40% to make it Tweedie-shaped (mass at zero)
    for i in 0..data.y.len() {
        if i % 5 < 2 {
            data.y[i] = 0.0;
        }
    }
    let split = split_vertical(&data, 3);
    let mut cfg = lr_config().with_seed(14);
    cfg.kind = GlmKind::Tweedie;
    cfg.learning_rate = 0.1;
    let rep = train(&split, &cfg).expect("train");
    let central = train_central(&data.x, &data.y, GlmKind::Tweedie, 0.1, 8);
    for (a, b) in rep.full_weights().iter().zip(&central.weights) {
        assert!((a - b).abs() < 2e-2, "weights diverged: {a} vs {b}");
    }
}

#[test]
fn rotating_cps_preserve_correctness() {
    let mut data = synthetic::blobs(200, 4);
    data.standardize();
    let split = split_vertical(&data, 2).replicate_hosts(2); // 3 parties

    let mut cfg = lr_config();
    cfg.cp_selection = CpSelection::Rotate;
    let rep = train(&split, &cfg).expect("train");
    // losses strictly decrease on separable data
    assert!(
        rep.losses.last().unwrap() < rep.losses.first().unwrap(),
        "loss did not improve: {:?}",
        rep.losses
    );
}

#[test]
fn mini_batch_training_learns() {
    let mut data = synthetic::blobs(600, 5);
    data.standardize();
    let split = split_vertical(&data, 2);

    let cfg = lr_config().with_batch(Some(128)).with_iterations(12);
    let rep = train(&split, &cfg).expect("train");
    let w = rep.full_weights();
    let wx = linalg::gemv(&data.x, &w);
    let auc = metrics::auc(&data.y, &wx);
    assert!(auc > 0.9, "mini-batch model failed to learn: auc={auc}");
}

#[test]
fn report_accounting_sane() {
    let mut data = synthetic::blobs(128, 6);
    data.standardize();
    let split = split_vertical(&data, 2);
    let rep = train(&split, &lr_config().with_iterations(3)).expect("train");
    assert!(rep.comm_mb > 0.0);
    assert!(rep.offline_mb > 0.0, "Beaver dealing must be accounted");
    assert!(rep.msgs > 10);
    assert!(rep.net_secs > 0.0);
    // distributed runtime = max(party cpu) + wire: it must include the
    // wire and cannot exceed the single-box wall time plus wire (parties
    // time-share one CPU here but run in parallel on the testbed)
    assert!(rep.runtime_secs() >= rep.net_secs);
    assert!(rep.runtime_secs() <= rep.wall_secs + rep.net_secs + 0.25);
    assert_eq!(rep.party_cpu_secs.len(), 2);
    assert_eq!(rep.losses.len(), 3);
}
