//! End-to-end correctness: the EFMVFL trainer must reproduce centralized
//! gradient descent (the protocol is lossless up to fixed-point noise).

use efmvfl::coordinator::{train, TrainConfig};
use efmvfl::data::{split_vertical, synthetic};
use efmvfl::glm::{train_central, GlmKind};
use efmvfl::linalg;
use efmvfl::metrics;
use efmvfl::protocols::CpSelection;

fn lr_config() -> TrainConfig {
    TrainConfig::logistic(2)
        .with_key_bits(256)
        .with_iterations(8)
        .with_batch(None)
        .with_seed(11)
}

#[test]
fn lr_two_party_matches_central() {
    let mut data = synthetic::blobs(300, 1);
    data.standardize();
    let split = split_vertical(&data, 2);

    let rep = train(&split, &lr_config()).expect("train");
    let central = train_central(&data.x, &data.y, GlmKind::Logistic, 0.15, 8);

    // weight trajectories agree to fixed-point noise
    let fed_w = rep.full_weights();
    for (a, b) in fed_w.iter().zip(&central.weights) {
        assert!((a - b).abs() < 1e-2, "weights diverged: {a} vs {b}");
    }
    // loss curves agree (federated reports the Taylor loss; on blobs the
    // early iterations stay in the small-|wx| regime where they match)
    for (i, (lf, lc)) in rep.losses.iter().zip(&central.losses).enumerate() {
        assert!((lf - lc).abs() < 0.05, "iter {i}: {lf} vs {lc}");
    }
    assert_eq!(rep.iterations_run, 8);
    assert!(rep.comm_mb > 0.0);
}

#[test]
fn lr_three_party_matches_central() {
    let mut data = synthetic::credit_default_like(400, 12, 2);
    data.standardize();
    let split = split_vertical(&data, 3);

    let rep = train(&split, &lr_config()).expect("train");
    let central = train_central(&data.x, &data.y, GlmKind::Logistic, 0.15, 8);

    let fed_w = rep.full_weights();
    assert_eq!(fed_w.len(), central.weights.len());
    for (a, b) in fed_w.iter().zip(&central.weights) {
        assert!((a - b).abs() < 1e-2, "weights diverged: {a} vs {b}");
    }
}

#[test]
fn pr_two_party_matches_central() {
    let mut data = synthetic::dvisits_like(400, 10, 3);
    data.standardize();
    let split = split_vertical(&data, 2);

    let cfg = TrainConfig::poisson(2)
        .with_key_bits(256)
        .with_iterations(8)
        .with_batch(None)
        .with_seed(12);
    let rep = train(&split, &cfg).expect("train");
    let central = train_central(&data.x, &data.y, GlmKind::Poisson, 0.1, 8);

    for (a, b) in rep.full_weights().iter().zip(&central.weights) {
        assert!((a - b).abs() < 2e-2, "weights diverged: {a} vs {b}");
    }
    for (i, (lf, lc)) in rep.losses.iter().zip(&central.losses).enumerate() {
        assert!((lf - lc).abs() < 0.05, "iter {i}: {lf} vs {lc}");
    }
}

#[test]
fn gamma_two_party_matches_central() {
    // the paper's "other GLMs" claim (§4.2): Gamma regression with the
    // same four protocols, shares of e^{−WX} instead of e^{WX}
    let mut data = synthetic::claims_severity_like(400, 8, 13);
    data.standardize();
    let split = split_vertical(&data, 2);
    let mut cfg = lr_config().with_seed(13);
    cfg.kind = GlmKind::Gamma;
    cfg.learning_rate = 0.1;
    let rep = train(&split, &cfg).expect("train");
    let central = train_central(&data.x, &data.y, GlmKind::Gamma, 0.1, 8);
    for (a, b) in rep.full_weights().iter().zip(&central.weights) {
        assert!((a - b).abs() < 2e-2, "weights diverged: {a} vs {b}");
    }
    for (lf, lc) in rep.losses.iter().zip(&central.losses) {
        assert!((lf - lc).abs() < 0.05, "loss: {lf} vs {lc}");
    }
}

#[test]
fn tweedie_three_party_matches_central() {
    let mut data = synthetic::claims_severity_like(300, 9, 14);
    data.standardize();
    // zero-inflate ~40% to make it Tweedie-shaped (mass at zero)
    for i in 0..data.y.len() {
        if i % 5 < 2 {
            data.y[i] = 0.0;
        }
    }
    let split = split_vertical(&data, 3);
    let mut cfg = lr_config().with_seed(14);
    cfg.kind = GlmKind::Tweedie;
    cfg.learning_rate = 0.1;
    let rep = train(&split, &cfg).expect("train");
    let central = train_central(&data.x, &data.y, GlmKind::Tweedie, 0.1, 8);
    for (a, b) in rep.full_weights().iter().zip(&central.weights) {
        assert!((a - b).abs() < 2e-2, "weights diverged: {a} vs {b}");
    }
}

#[test]
fn rotating_cps_preserve_correctness() {
    let mut data = synthetic::blobs(200, 4);
    data.standardize();
    let split = split_vertical(&data, 2).replicate_hosts(2); // 3 parties

    let mut cfg = lr_config();
    cfg.cp_selection = CpSelection::Rotate;
    let rep = train(&split, &cfg).expect("train");
    // losses strictly decrease on separable data
    assert!(
        rep.losses.last().unwrap() < rep.losses.first().unwrap(),
        "loss did not improve: {:?}",
        rep.losses
    );
}

#[test]
fn mini_batch_training_learns() {
    let mut data = synthetic::blobs(600, 5);
    data.standardize();
    let split = split_vertical(&data, 2);

    let cfg = lr_config().with_batch(Some(128)).with_iterations(12);
    let rep = train(&split, &cfg).expect("train");
    let w = rep.full_weights();
    let wx = linalg::gemv(&data.x, &w);
    let auc = metrics::auc(&data.y, &wx);
    assert!(auc > 0.9, "mini-batch model failed to learn: auc={auc}");
}

#[test]
fn shuffled_schedule_agrees_across_parties_and_reruns() {
    use efmvfl::protocols::plane::BatchSchedule;
    // every party builds the schedule from shared config only — two
    // independently constructed instances (one per "party") must gather
    // identical rows each iteration, and each epoch must partition the
    // dataset
    let party_a = BatchSchedule::new(600, Some(128), true, 11);
    let party_b = BatchSchedule::new(600, Some(128), true, 11);
    let per_epoch = party_a.batches_per_epoch();
    assert_eq!(per_epoch, 5);
    for t in 0..3 * per_epoch {
        assert_eq!(party_a.rows_at(t), party_b.rows_at(t), "parties disagree at t={t}");
    }
    let mut epoch0: Vec<usize> = (0..per_epoch).flat_map(|s| party_a.rows_at(s)).collect();
    epoch0.sort_unstable();
    assert_eq!(epoch0, (0..600).collect::<Vec<_>>());

    // end to end: a shuffled mini-batch run is a pure function of the
    // seed (bit-identical on rerun), and the seed actually matters
    let mut data = synthetic::blobs(240, 5);
    data.standardize();
    let split = split_vertical(&data, 2);
    let cfg = lr_config().with_batch(Some(64)).with_iterations(6);
    let a = train(&split, &cfg).expect("train");
    let b = train(&split, &cfg).expect("train rerun");
    assert_eq!(a.losses, b.losses, "shuffled run not reproducible");
    assert_eq!(a.weights, b.weights);
    let other = train(&split, &cfg.clone().with_seed(12)).expect("train reseeded");
    assert_ne!(a.losses, other.losses, "reseeding did not reshuffle");
}

#[test]
fn shuffled_mini_batch_lr_matches_central_loss_band() {
    let mut data = synthetic::blobs(600, 5);
    data.standardize();
    let split = split_vertical(&data, 2);

    // 128-row batches over 600 rows -> 5 batches/epoch; 20 iterations =
    // 4 epochs of seed-agreed shuffled SGD (shuffle defaults on)
    let cfg = lr_config().with_batch(Some(128)).with_iterations(20);
    let rep = train(&split, &cfg).expect("train");
    let central = train_central(&data.x, &data.y, GlmKind::Logistic, 0.15, 20);

    // converges into the same loss band as centralized full-batch GD:
    // batch losses are sampled on 128 rows, so average the tail to
    // smooth the mini-batch noise before comparing
    let tail: f64 = rep.losses[17..].iter().sum::<f64>() / 3.0;
    let central_final = *central.losses.last().unwrap();
    assert!(
        (tail - central_final).abs() < 0.15,
        "shuffled SGD tail loss {tail:.4} left central's band ({central_final:.4})"
    );
    assert!(
        rep.losses.last().unwrap() < rep.losses.first().unwrap(),
        "loss did not improve: {:?}",
        rep.losses
    );
    // and the model itself is good on the full dataset
    let wx = linalg::gemv(&data.x, &rep.full_weights());
    assert!(metrics::auc(&data.y, &wx) > 0.9);
}

#[test]
fn report_accounting_sane() {
    let mut data = synthetic::blobs(128, 6);
    data.standardize();
    let split = split_vertical(&data, 2);
    let rep = train(&split, &lr_config().with_iterations(3)).expect("train");
    assert!(rep.comm_mb > 0.0);
    assert!(rep.offline_mb > 0.0, "Beaver dealing must be accounted");
    assert!(rep.msgs > 10);
    assert!(rep.net_secs > 0.0);
    // distributed runtime = max(party cpu) + wire: it must include the
    // wire and cannot exceed the single-box wall time plus wire (parties
    // time-share one CPU here but run in parallel on the testbed)
    assert!(rep.runtime_secs() >= rep.net_secs);
    assert!(rep.runtime_secs() <= rep.wall_secs + rep.net_secs + 0.25);
    assert_eq!(rep.party_cpu_secs.len(), 2);
    assert_eq!(rep.losses.len(), 3);
}
