//! Cross-framework parity: all four frameworks must train essentially
//! the same model on the same data (they compute the same gradients —
//! securely, by different means), while their communication profiles
//! must show the paper's §5.3 ordering.

use efmvfl::baselines::Framework;
use efmvfl::coordinator::TrainConfig;
use efmvfl::data::{split_vertical, synthetic};
use efmvfl::glm::GlmKind;
use efmvfl::linalg;
use efmvfl::metrics;

const FRAMEWORKS: [Framework; 4] = [
    Framework::Efmvfl,
    Framework::ThirdParty,
    Framework::SecretShare,
    Framework::SsHe,
];

#[test]
fn all_frameworks_learn_the_same_lr_model() {
    let mut data = synthetic::credit_default_like(400, 12, 5);
    data.standardize();
    let split = split_vertical(&data, 2);
    let cfg = TrainConfig::logistic(2)
        .with_key_bits(256)
        .with_iterations(6)
        .with_batch(None)
        .with_seed(55);

    let mut weight_sets = Vec::new();
    for fw in FRAMEWORKS {
        let rep = fw.train(&split, &cfg).unwrap();
        assert_eq!(rep.iterations_run, 6, "{:?} stopped early", fw);
        weight_sets.push((fw, rep.full_weights()));
    }
    let (_, reference) = &weight_sets[0];
    for (fw, w) in &weight_sets[1..] {
        for (a, b) in w.iter().zip(reference) {
            assert!(
                (a - b).abs() < 3e-2,
                "{fw:?} diverged from EFMVFL: {a} vs {b}"
            );
        }
    }
}

#[test]
fn comm_ordering_matches_paper() {
    // Paper Table 1 ordering among no-third-party frameworks:
    //   SS-LR ≫ SS-HE-LR > EFMVFL-LR.
    let mut data = synthetic::credit_default_like(512, 16, 6);
    data.standardize();
    let split = split_vertical(&data, 2);
    let cfg = TrainConfig::logistic(2)
        .with_key_bits(256)
        .with_iterations(4)
        .with_batch(Some(256))
        .with_seed(56);

    let efmvfl = Framework::Efmvfl.train(&split, &cfg).unwrap();
    let ss = Framework::SecretShare.train(&split, &cfg).unwrap();
    let ss_he = Framework::SsHe.train(&split, &cfg).unwrap();

    assert!(
        ss.comm_mb > ss_he.comm_mb,
        "SS ({}) must exceed SS-HE ({})",
        ss.comm_mb,
        ss_he.comm_mb
    );
    assert!(
        ss_he.comm_mb > efmvfl.comm_mb,
        "SS-HE ({}) must exceed EFMVFL ({})",
        ss_he.comm_mb,
        efmvfl.comm_mb
    );
}

#[test]
fn tp_and_efmvfl_agree_on_poisson() {
    let mut data = synthetic::dvisits_like(300, 10, 7);
    data.standardize();
    let split = split_vertical(&data, 2);
    let mut cfg = TrainConfig::poisson(2)
        .with_key_bits(256)
        .with_iterations(5)
        .with_batch(None)
        .with_seed(57);
    cfg.kind = GlmKind::Poisson;

    let ours = Framework::Efmvfl.train(&split, &cfg).unwrap();
    let tp = Framework::ThirdParty.train(&split, &cfg).unwrap();

    for (a, b) in ours.full_weights().iter().zip(&tp.full_weights()) {
        assert!((a - b).abs() < 3e-2, "{a} vs {b}");
    }
    // losses (both exact-form PR NLL) nearly identical — Figure 1 lower
    for (la, lb) in ours.losses.iter().zip(&tp.losses) {
        assert!((la - lb).abs() < 0.02, "{la} vs {lb}");
    }
    // both models predict usefully
    let wx = linalg::gemv(&data.x, &ours.full_weights());
    let pred: Vec<f64> = wx.iter().map(|&z| z.exp()).collect();
    assert!(metrics::mae(&data.y, &pred) < 1.0);
}

#[test]
fn framework_labels_and_parsing() {
    assert_eq!(Framework::Efmvfl.label(GlmKind::Logistic), "EFMVFL-LR");
    assert_eq!(Framework::ThirdParty.label(GlmKind::Poisson), "TP-PR");
    assert_eq!(Framework::SecretShare.label(GlmKind::Logistic), "SS-LR");
    assert_eq!(Framework::SsHe.label(GlmKind::Logistic), "SS-HE-LR");
    assert_eq!(Framework::parse("caesar"), Some(Framework::SsHe));
    assert_eq!(Framework::parse("efmvfl"), Some(Framework::Efmvfl));
    assert_eq!(Framework::parse("nope"), None);
}
