//! Telemetry-plane acceptance: a traced 3-party in-process run must
//! emit schema-valid JSONL spans covering every pipeline stage of every
//! iteration (plus at least one protocol span per iteration), the
//! merged metrics registry must agree with the comm report and render
//! as Prometheus text, and turning tracing off must leave the model
//! plane bit-identical — weights, losses, message counts — while a
//! traced run's extra wire bytes are exactly the trace-context
//! envelopes it carried.

use efmvfl::benchkit::Json;
use efmvfl::coordinator::{train, TrainConfig};
use efmvfl::data::{split_vertical, synthetic};
use efmvfl::obs::{parse_flat_record, PIPELINE_STAGES};
use std::collections::{BTreeSet, HashMap};

const PARTIES: usize = 3;
const ITERS: usize = 4;

fn cfg() -> TrainConfig {
    TrainConfig::logistic(PARTIES)
        .with_key_bits(256)
        .with_iterations(ITERS)
        .with_batch(Some(64))
        .with_seed(21)
}

fn fresh_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn traced_run_covers_every_stage_of_every_iteration() {
    let mut data = synthetic::credit_default_like(200, 9, 21);
    data.standardize();
    let split = split_vertical(&data, PARTIES);
    let dir = fresh_dir("efmvfl_trace_obs_coverage");
    let cfg = cfg().with_trace_dir(dir.to_str().unwrap());
    let rep = train(&split, &cfg).expect("train");
    assert!(rep.iterations_run >= 1);

    for party in 0..PARTIES {
        let path = dir.join(format!("party-{party}.jsonl"));
        let text = std::fs::read_to_string(&path).expect("per-party trace file");
        let mut spans: HashMap<(String, u64), u64> = HashMap::new();
        let mut proto_rounds: BTreeSet<u64> = BTreeSet::new();
        for line in text.lines() {
            // every record must parse as a flat JSON object (the schema)
            let rec = parse_flat_record(line).expect("schema-valid record");
            let get = |k: &str| rec.iter().find(|(key, _)| key == k).map(|(_, v)| v.clone());
            assert_eq!(get("party"), Some(Json::Int(party as u64)), "{line}");
            match get("kind") {
                Some(Json::Str(kind)) if kind == "span" => {
                    let Some(Json::Str(stage)) = get("stage") else {
                        panic!("span without stage: {line}")
                    };
                    let Some(Json::Int(t)) = get("t") else { panic!("span without t: {line}") };
                    assert!(matches!(get("wall_s"), Some(Json::Num(v)) if v >= 0.0), "{line}");
                    assert!(matches!(get("ct_exps"), Some(Json::Int(_))), "{line}");
                    if stage == "proto" {
                        assert!(matches!(get("proto"), Some(Json::Str(_))), "{line}");
                        proto_rounds.insert(t);
                    }
                    *spans.entry((stage, t)).or_default() += 1;
                }
                Some(Json::Str(_)) => {} // events (net rows, …) need no stage
                other => panic!("record without kind: {other:?} in {line}"),
            }
        }
        for t in 0..rep.iterations_run as u64 {
            for stage in PIPELINE_STAGES {
                assert!(
                    spans.contains_key(&(stage.to_string(), t)),
                    "party {party}: missing {stage} span for iteration {t}"
                );
            }
            assert!(
                proto_rounds.contains(&t),
                "party {party}: no protocol span in iteration {t}"
            );
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn tracing_off_is_bit_identical_to_tracing_on() {
    let mut data = synthetic::credit_default_like(150, 7, 5);
    data.standardize();
    let split = split_vertical(&data, PARTIES);
    let dir = fresh_dir("efmvfl_trace_obs_identity");
    let traced_cfg = cfg().with_trace_dir(dir.to_str().unwrap());
    let traced = train(&split, &traced_cfg).expect("traced train");
    let plain = train(&split, &cfg()).expect("untraced train");
    // the tracer must stay off the RNG streams and the model plane:
    // weights, loss curve, message counts, and offline bytes agree
    // bit-for-bit
    assert_eq!(traced.weights, plain.weights, "weights must be bit-identical");
    assert_eq!(traced.losses, plain.losses, "loss curves must be bit-identical");
    assert_eq!(traced.offline_mb, plain.offline_mb);
    assert_eq!(traced.msgs, plain.msgs);
    assert_eq!(traced.iterations_run, plain.iterations_run);
    // wire bytes: a traced run carries one fixed-size trace-context
    // envelope per counted send, and those bytes are accounted exactly —
    // the link totals differ from the plain run by precisely the trace
    // class, which the plain run must not have at all
    let link_total = |m: &efmvfl::obs::MetricsRegistry| -> u64 {
        (0..PARTIES)
            .flat_map(|from| (0..PARTIES).map(move |to| (from, to)))
            .map(|(from, to)| {
                m.counter(&format!("efmvfl_link_bytes_total{{from=\"{from}\",to=\"{to}\"}}"))
            })
            .sum()
    };
    assert_eq!(plain.metrics.counter("efmvfl_trace_bytes_total"), 0);
    let trace_bytes = traced.metrics.counter("efmvfl_trace_bytes_total");
    assert!(trace_bytes > 0, "traced run recorded no envelope bytes");
    assert_eq!(
        trace_bytes % efmvfl::net::TRACE_ENVELOPE_BYTES as u64,
        0,
        "trace bytes must be a whole number of envelopes"
    );
    assert_eq!(
        link_total(&traced.metrics),
        link_total(&plain.metrics) + trace_bytes,
        "traced wire bytes must exceed plain by exactly the envelope bytes"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn merged_registry_matches_the_run_and_renders_as_prometheus() {
    let mut data = synthetic::credit_default_like(180, 8, 9);
    data.standardize();
    let split = split_vertical(&data, PARTIES);
    let rep = train(&split, &cfg()).expect("train");
    let m = &rep.metrics;
    // per-stage wall histograms: one sample per run iteration per party
    for party in 0..PARTIES {
        for stage in PIPELINE_STAGES {
            let key = format!("efmvfl_stage_wall_seconds{{party=\"{party}\",stage=\"{stage}\"}}");
            let h = m.histogram(&key).unwrap_or_else(|| panic!("missing {key}"));
            assert_eq!(h.count(), rep.iterations_run, "{key}");
        }
        let iters = m.counter(&format!("efmvfl_iterations_total{{party=\"{party}\"}}"));
        assert_eq!(iters as usize, rep.iterations_run);
    }
    // the absorbed NetStats: ciphertexts moved, and some link carried them
    assert!(m.counter("efmvfl_cipher_bytes_total") > 0, "no cipher bytes absorbed");
    let link_bytes: u64 = (0..PARTIES)
        .flat_map(|from| (0..PARTIES).map(move |to| (from, to)))
        .map(|(from, to)| {
            m.counter(&format!("efmvfl_link_bytes_total{{from=\"{from}\",to=\"{to}\"}}"))
        })
        .sum();
    assert!(link_bytes > 0, "no per-link traffic absorbed");
    // and the whole registry renders as Prometheus text exposition
    let prom = m.to_prometheus();
    assert!(prom.contains("# TYPE efmvfl_stage_wall_seconds summary"), "{prom}");
    assert!(prom.contains("efmvfl_cipher_bytes_total"), "{prom}");
    assert!(prom.lines().all(|l| l.starts_with('#') || l.split_whitespace().count() == 2));
}
