//! Runtime bridge tests: the AOT artifacts loaded through PJRT must
//! agree with native compute, and the trainer must work end-to-end with
//! `use_xla = true`.
//!
//! Compiled only with `--features xla` (the default offline build ships
//! a stub engine); additionally skipped (with a notice) when
//! `artifacts/` hasn't been built — run `make artifacts` first.

#![cfg(feature = "xla")]

use efmvfl::coordinator::{train, TrainConfig};
use efmvfl::crypto::prng::ChaChaRng;
use efmvfl::data::{split_vertical, synthetic};
use efmvfl::linalg::{self, Matrix};
use efmvfl::runtime::engine::XlaEngine;
use efmvfl::runtime::Compute;

fn engine() -> Option<XlaEngine> {
    match XlaEngine::load_default() {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping runtime tests (run `make artifacts`): {err}");
            None
        }
    }
}

#[test]
fn xla_gemv_matches_native() {
    let Some(eng) = engine() else { return };
    let mut rng = ChaChaRng::from_seed(80);
    for (m, f) in [(100, 8), (1024, 32), (1500, 24), (1, 1)] {
        let x = Matrix::random(m, f, &mut rng);
        let w: Vec<f64> = (0..f).map(|_| rng.next_gaussian()).collect();
        let got = eng.gemv(&x, &w);
        let want = linalg::gemv(&x, &w);
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{m}x{f}: {a} vs {b}");
        }
    }
}

#[test]
fn xla_exp_matches_native() {
    let Some(eng) = engine() else { return };
    let z: Vec<f64> = (0..2500).map(|i| (i as f64 / 500.0) - 2.5).collect();
    let got = eng.exp(&z);
    for (a, b) in got.iter().zip(z.iter().map(|&v| v.exp())) {
        assert!((a - b).abs() < 1e-3 * (1.0 + b), "{a} vs {b}");
    }
}

#[test]
fn xla_gemv_t_matches_native() {
    let Some(eng) = engine() else { return };
    let mut rng = ChaChaRng::from_seed(81);
    let x = Matrix::random(700, 16, &mut rng);
    let d: Vec<f64> = (0..700).map(|_| rng.next_gaussian()).collect();
    let got = eng.gemv_t_tiled(&x, &d).unwrap();
    let want = linalg::gemv_t(&x, &d);
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 2e-2 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

#[test]
fn training_through_pjrt_matches_native() {
    let Some(_) = engine() else { return };
    let mut data = synthetic::blobs(300, 9);
    data.standardize();
    let split = split_vertical(&data, 2);
    let cfg = TrainConfig::logistic(2)
        .with_key_bits(256)
        .with_iterations(5)
        .with_batch(None)
        .with_seed(82);

    let native = train(&split, &cfg).unwrap();
    let mut cfg_xla = cfg.clone();
    cfg_xla.use_xla = true;
    let xla = train(&split, &cfg_xla).unwrap();

    for (a, b) in xla.full_weights().iter().zip(&native.full_weights()) {
        assert!((a - b).abs() < 1e-2, "weights: {a} vs {b}");
    }
    for (la, lb) in xla.losses.iter().zip(&native.losses) {
        assert!((la - lb).abs() < 1e-2, "loss: {la} vs {lb}");
    }
}
