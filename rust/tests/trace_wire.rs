//! Wire-honesty acceptance for trace-context propagation: on a real
//! 3-party loopback-TCP mesh, a run with tracing disabled must put
//! **zero** trace bytes on the wire (byte-identical totals to an
//! uninstrumented build), and a traced run's extra wire bytes must be
//! *exactly* the fixed-size trace-context envelopes it sent — no more,
//! no less — while the model plane (weights, losses, message counts)
//! stays bit-identical either way.

use efmvfl::coordinator::{distributed, TrainConfig};
use efmvfl::data::{split_vertical, synthetic};
use efmvfl::net::tcp::{bind_ephemeral_roster, connect_mesh_with_listener};
use efmvfl::net::TRACE_ENVELOPE_BYTES;
use std::time::Duration;

const PARTIES: usize = 3;

fn cfg() -> TrainConfig {
    TrainConfig::logistic(PARTIES)
        .with_key_bits(256)
        .with_iterations(3)
        .with_batch(Some(64))
        .with_seed(13)
}

/// Run a full distributed training over real loopback sockets and
/// return every party's report (party 0 carries the gathered totals).
fn tcp_run(cfg: &TrainConfig) -> Vec<distributed::PartyReport> {
    let mut data = synthetic::credit_default_like(150, 7, 13);
    data.standardize();
    let split = split_vertical(&data, PARTIES);
    let (roster, listeners) = bind_ephemeral_roster(PARTIES).expect("ephemeral roster");
    let mut handles = Vec::with_capacity(PARTIES);
    for (p, listener) in listeners.into_iter().enumerate() {
        let roster = roster.clone();
        let cfg = cfg.clone();
        let x = split.party_block(p).clone();
        let y = (p == 0).then(|| split.y.clone());
        handles.push(std::thread::spawn(move || {
            let transport =
                connect_mesh_with_listener(&roster, p, listener, Duration::from_secs(30))
                    .expect("mesh bootstrap");
            distributed::train_party(transport, x, y, &cfg).expect("distributed train")
        }));
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

#[test]
fn trace_envelopes_are_exactly_accounted_on_a_tcp_mesh() {
    let dir = std::env::temp_dir().join("efmvfl_trace_wire_parity");
    let _ = std::fs::remove_dir_all(&dir);
    let plain = tcp_run(&cfg());
    let traced = tcp_run(&cfg().with_trace_dir(dir.to_str().unwrap()));

    // the model plane is untouched by tracing: every party's weights,
    // C's loss curve, and the message totals agree bit-for-bit
    for (p, (tr, pl)) in traced.iter().zip(&plain).enumerate() {
        assert_eq!(tr.party_id, p);
        assert_eq!(tr.weights, pl.weights, "party {p}: weights diverged under tracing");
    }
    assert_eq!(traced[0].losses, plain[0].losses, "loss curves diverged under tracing");
    assert_eq!(traced[0].iterations_run, plain[0].iterations_run);

    let plain_comm = plain[0].comm.as_ref().expect("party 0 gathers comm totals");
    let traced_comm = traced[0].comm.as_ref().expect("party 0 gathers comm totals");
    assert_eq!(traced_comm.msgs, plain_comm.msgs, "message totals diverged under tracing");

    // tracing off ⇒ zero trace bytes anywhere: neither the gathered
    // comm report nor the merged registry carries a trace class
    assert_eq!(plain_comm.trace_mb, 0.0, "untraced run put trace bytes on the wire");
    assert_eq!(plain[0].metrics.counter("efmvfl_trace_bytes_total"), 0);

    // tracing on ⇒ the overhead is a whole number of fixed-size
    // envelopes, and the wire totals differ by exactly that class
    let trace_bytes = traced[0].metrics.counter("efmvfl_trace_bytes_total");
    assert!(trace_bytes > 0, "traced run recorded no envelope bytes");
    assert_eq!(
        trace_bytes % TRACE_ENVELOPE_BYTES as u64,
        0,
        "trace bytes must be a whole number of {TRACE_ENVELOPE_BYTES}-byte envelopes"
    );
    assert_eq!(traced_comm.trace_mb, trace_bytes as f64 / 1e6);
    assert_eq!(
        traced_comm.total_bytes,
        plain_comm.total_bytes + trace_bytes,
        "traced wire bytes must exceed plain by exactly the envelope bytes"
    );

    // and the traced run actually left a causal trail: one JSONL file
    // per party in the shared trace dir
    for p in 0..PARTIES {
        let path = dir.join(format!("party-{p}.jsonl"));
        assert!(path.exists(), "missing trace file {}", path.display());
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
