//! Security-property tests (paper §4.4): what each party *sees* during
//! training must be independent of the other parties' secrets.
//!
//! These are empirical audits, not proofs — they check the mechanisms the
//! theorems rely on: uniform shares, semantically-secure ciphertexts,
//! statistically-hiding masks, and shape-only-dependent traffic.

use efmvfl::coordinator::{train, TrainConfig};
use efmvfl::crypto::he_ops::{self, MASK_BITS};
use efmvfl::crypto::paillier::Keypair;
use efmvfl::crypto::prng::ChaChaRng;
use efmvfl::data::{split_vertical, synthetic, Dataset};
use efmvfl::linalg::Matrix;

fn cfg() -> TrainConfig {
    TrainConfig::logistic(2)
        .with_key_bits(256)
        .with_iterations(4)
        .with_batch(None)
        .with_seed(77)
}

/// The decrypting CP's view in Protocol 3 is `v + R` with `R` uniform
/// over ≥180 bits: the view's high bits must be mask-dominated and two
/// different `v`s must produce unrelated views.
#[test]
fn decryptor_view_is_mask_dominated() {
    let mut rng = ChaChaRng::from_seed(70);
    let kp = Keypair::generate(256, &mut rng);
    let x = Matrix::random(16, 4, &mut rng);

    let mut views = Vec::new();
    for scale in [1.0f64, -1000.0] {
        let d: Vec<i128> = (0..16)
            .map(|i| efmvfl::crypto::fixed::encode(scale * (i as f64 - 8.0)))
            .collect();
        let cts: Vec<_> = d.iter().map(|&v| kp.pk.encrypt_i128(v, &mut rng)).collect();
        let enc_g = he_ops::he_matvec_t(&kp.pk, &cts, &x);
        for ct in &enc_g {
            let (masked, _r) = he_ops::mask_ct(&kp.pk, ct, &mut rng);
            let seen = kp.sk.decrypt_raw(&masked);
            // the payload is < 2^90 here; the view must be ≥ mask-sized
            assert!(
                seen.bit_len() >= MASK_BITS - 16,
                "view leaks payload magnitude: {} bits",
                seen.bit_len()
            );
            views.push(seen);
        }
    }
    // no accidental view collisions across different payloads
    for i in 0..views.len() {
        for j in i + 1..views.len() {
            assert_ne!(views[i], views[j], "repeated decryptor view");
        }
    }
}

/// Online traffic must be a function of *shapes only*: two runs with
/// different labels and features (same dims) produce byte-identical
/// traffic volume — nothing about the values leaks into message sizes.
#[test]
fn traffic_depends_on_shapes_only() {
    let run = |seed: u64| {
        let mut data = synthetic::credit_default_like(200, 10, seed);
        data.standardize();
        let split = split_vertical(&data, 3);
        let rep = train(&split, &cfg()).unwrap();
        (rep.comm_mb, rep.msgs)
    };
    let (mb_a, msgs_a) = run(1);
    let (mb_b, msgs_b) = run(999);
    assert_eq!(msgs_a, msgs_b, "message count depends on data values");
    assert!(
        (mb_a - mb_b).abs() < 1e-9,
        "byte volume depends on data values: {mb_a} vs {mb_b}"
    );
}

/// Fixed-point encoding of the labels must not leak through the shares:
/// the first CP's share of Y is uniform regardless of the label values.
#[test]
fn label_shares_uniform() {
    use efmvfl::mpc::{ring, share::share_vec};
    let mut rng = ChaChaRng::from_seed(71);
    for labels in [vec![1.0f64; 4096], vec![-1.0f64; 4096]] {
        let enc = ring::encode_vec(&labels);
        let (s0, _s1) = share_vec(&enc, &mut rng);
        let mut seen = [false; 256];
        for &e in &s0.0 {
            seen[(e >> 56) as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() > 240);
    }
}

/// Adversarial values at the fixed-point range edges must not panic or
/// overflow the protocol stack (standardize + the Z clamp bound them).
#[test]
fn extreme_values_do_not_break_protocols() {
    let rows = 64;
    let mut x = Matrix::zeros(rows, 4);
    for i in 0..rows {
        for j in 0..4 {
            x.set(i, j, if (i + j) % 2 == 0 { 1e6 } else { -1e6 });
        }
    }
    let y: Vec<f64> = (0..rows).map(|i| (i % 2) as f64).collect();
    let mut data = Dataset { x, y, name: "extreme".into() };
    data.standardize();
    let split = split_vertical(&data, 2);
    let rep = train(&split, &cfg().with_iterations(2)).unwrap();
    assert!(rep.losses.iter().all(|l| l.is_finite()));
}
