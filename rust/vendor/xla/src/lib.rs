//! Offline **type-check stub** for the `xla` (PJRT) bindings.
//!
//! The real crate links libxla/PJRT and is unavailable in the offline
//! build environment. This stub mirrors the exact API surface the
//! feature-gated [`runtime engine`](../../src/runtime/engine.rs) uses, so
//! `cargo check --features xla` type-checks the engine without network or
//! native libraries. Every runtime entry point fails fast from
//! [`PjRtClient::cpu`], which makes the engine's loader return an error
//! and the backend registry fall back to the pure-Rust `linalg` backend.
//!
//! Swapping in the real PJRT bindings is a Cargo.toml change only; no
//! engine code changes.

use std::fmt;

/// Error type matching the shape the engine formats with `{e:?}`.
pub struct XlaError {
    msg: String,
}

impl XlaError {
    fn new(msg: &str) -> XlaError {
        XlaError { msg: msg.to_string() }
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.msg)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for XlaError {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError::new(&format!(
        "{what}: PJRT unavailable (built against the offline xla stub)"
    )))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client. The stub always errors, which sends the
    /// engine loader down its graceful-fallback path.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments; returns per-device, per-output
    /// buffers.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer produced by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to the host as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A host-side tensor value.
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Unwrap a single-element tuple literal.
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: Copy + Default>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an `.hlo.txt` artifact. The stub errors so `XlaEngine::load`
    /// reports the missing toolchain instead of pretending to compile.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_fast() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let shown = format!("{err:?}");
        assert!(shown.contains("PJRT unavailable"), "{shown}");
    }

    #[test]
    fn literal_builders_exist() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
