//! Vendored, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment is offline, so the workspace carries the subset
//! of anyhow's API that EFMVFL actually uses as a local path crate named
//! `anyhow` — call sites (`use anyhow::{anyhow, bail, Context, Result}`)
//! are identical to the real crate, and swapping the registry crate back
//! in is a one-line Cargo.toml change.
//!
//! Provided surface:
//!
//! - [`Error`]: an opaque error carrying a message and an optional source
//!   chain; converts from any `std::error::Error + Send + Sync + 'static`
//!   via `?`.
//! - [`Result<T>`]: alias with `Error` as the default error type.
//! - [`Context`]: `.context(msg)` / `.with_context(|| msg)` on `Result`
//!   and `Option`.
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque, heap-cheap error value with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap a concrete error, keeping it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prefix the error with higher-level context (consuming form, used
    /// by the [`Context`] trait).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The captured source error, if any.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        match &self.source {
            Some(boxed) => Some(&**boxed),
            None => None,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur: Option<&(dyn StdError + 'static)> = self.source();
        let mut first = true;
        while let Some(err) = cur {
            if first {
                write!(f, "\n\nCaused by:")?;
                first = false;
            }
            write!(f, "\n    {err}")?;
            cur = err.source();
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that keeps this blanket conversion coherent (same trick as the real
// anyhow crate) so `?` works on any concrete error type.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Attach context to errors, anyhow-style.
///
/// The second type parameter mirrors the real crate's signature: it keeps
/// the `Result` and `Option` impls trivially non-overlapping under
/// stable coherence rules.
pub trait Context<T, E> {
    /// Wrap the error with a static-ish context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error with lazily-built context.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/here/ever")
            .with_context(|| "reading the missing file".to_string())?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        let shown = format!("{err}");
        assert!(shown.starts_with("reading the missing file: "), "{shown}");
        assert!(err.source().is_some());
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 3;
        let e = anyhow!("value {n} and {}", 7);
        assert_eq!(e.to_string(), "value 3 and 7");
        fn bails() -> Result<()> {
            bail!("stopped at {}", 42);
        }
        assert_eq!(bails().unwrap_err().to_string(), "stopped at 42");
        fn ensures(v: i32) -> Result<()> {
            ensure!(v > 0, "need positive, got {v}");
            Ok(())
        }
        assert!(ensures(1).is_ok());
        assert_eq!(
            ensures(-1).unwrap_err().to_string(),
            "need positive, got -1"
        );
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
        assert_eq!(Some(5u32).context("unused").unwrap(), 5);
    }

    #[test]
    fn parse_context_chains() {
        let r: Result<usize> = "abc".parse::<usize>().context("parties");
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("parties: "), "{msg}");
    }

    #[test]
    fn debug_renders_cause_chain() {
        let err = io_fail().unwrap_err();
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }
}
