//! **Table 2 reproduction** — Poisson regression on dvisits-like data,
//! 2 parties: `mae / rmse / comm / runtime` for TP-PR and EFMVFL-PR.
//!
//! Paper's rows: TP-PR 0.571/0.834/4.27MB/12.44s ·
//! EFMVFL-PR 0.571/0.834/5.60MB/10.78s — both reach identical accuracy
//! (the protocols are lossless), EFMVFL slightly cheaper in runtime with
//! slightly more comm than the packed-HE TP. Shape target here:
//! identical mae/rmse between the two, EFMVFL runtime ≤ TP runtime.

use efmvfl::baselines::Framework;
use efmvfl::benchkit::{print_table, BenchScale};
use efmvfl::coordinator::TrainConfig;
use efmvfl::data::{csv, split_vertical, synthetic};
use efmvfl::glm::GlmKind;
use efmvfl::{linalg, metrics};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let scale = BenchScale::from_env();
    // dvisits scale: 5190 × 18 regardless of the LR bench's sample knob
    let samples = scale.samples.min(5_190);
    let mut data = synthetic::dvisits_like(samples, 18, 11);
    data.standardize();
    let mut rng = efmvfl::crypto::prng::ChaChaRng::from_seed(11);
    let (train_set, test_set) = data.train_test_split(0.7, &mut rng);
    let split = split_vertical(&train_set, 2);
    println!(
        "Table 2: PR on {} ({} train / {} test, {}-bit keys, batch {}, {} iters)\n",
        data.name, train_set.len(), test_set.len(),
        scale.key_bits, scale.batch, scale.iterations
    );

    let cfg = TrainConfig::poisson(2)
        .with_key_bits(scale.key_bits)
        .with_iterations(scale.iterations)
        .with_batch(Some(scale.batch))
        .with_seed(11);

    let mut rows = Vec::new();
    let mut csv_cols: Vec<Vec<f64>> = vec![Vec::new(); 4];
    for fw in [Framework::ThirdParty, Framework::Efmvfl] {
        let label = fw.label(GlmKind::Poisson);
        eprintln!("running {label} ...");
        let rep = fw.train(&split, &cfg)?;
        let wx = linalg::gemv(&test_set.x, &rep.full_weights());
        let pred: Vec<f64> = wx.iter().map(|&z| z.exp()).collect();
        let mae = metrics::mae(&test_set.y, &pred);
        let rmse = metrics::rmse(&test_set.y, &pred);
        rows.push(vec![
            label,
            format!("{mae:.3}"),
            format!("{rmse:.3}"),
            format!("{:.2}mb", rep.comm_mb),
            format!("{:.2}s", rep.runtime_secs()),
        ]);
        csv_cols[0].push(mae);
        csv_cols[1].push(rmse);
        csv_cols[2].push(rep.comm_mb);
        csv_cols[3].push(rep.runtime_secs());
    }

    print_table(&["framework", "mae", "rmse", "comm", "runtime"], &rows);
    csv::write_columns(
        Path::new("out/table2_pr.csv"),
        &["mae", "rmse", "comm_mb", "runtime_s"],
        &csv_cols,
    )?;
    println!("\nwritten to out/table2_pr.csv (rows: TP, EFMVFL)");
    Ok(())
}
