//! Ablation studies over the design choices DESIGN.md calls out:
//!
//! 1. **Key size** — Paillier 256/512/1024 bits: ciphertext traffic and
//!    HE compute scale quadratically-ish; accuracy must not move (the
//!    protocol is exact regardless of key size).
//! 2. **Batch size** — comm per iteration is linear in the batch; runtime
//!    amortizes fixed per-iteration costs.
//! 3. **CP selection** — `Fixed (C,B1)` vs `Rotate` (anti-collusion,
//!    §4.3): rotation pushes C out of the CP pair in some iterations,
//!    adding the non-CP double-product cost to C.
//! 4. **Obfuscator pool** — pre-generated `rⁿ` vs fresh per encryption.
//!
//! Run: `cargo bench --bench ablation` (EFMVFL_BENCH_FAST=1 to shrink).

use efmvfl::benchkit::print_table;
use efmvfl::coordinator::{train, TrainConfig};
use efmvfl::data::{split_vertical, synthetic};
use efmvfl::metrics;
use efmvfl::protocols::CpSelection;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("EFMVFL_BENCH_FAST").is_ok();
    let samples = if fast { 2_000 } else { 8_000 };
    let iters = if fast { 4 } else { 10 };

    let mut data = synthetic::credit_default_like(samples, 16, 13);
    data.standardize();
    let mut rng = efmvfl::crypto::prng::ChaChaRng::from_seed(13);
    let (train_set, test_set) = data.train_test_split(0.7, &mut rng);
    let split = split_vertical(&train_set, 2);
    let base = TrainConfig::logistic(2)
        .with_iterations(iters)
        .with_batch(Some(512))
        .with_seed(13);

    let auc_of = |w: &[f64]| {
        let wx = efmvfl::linalg::gemv(&test_set.x, w);
        metrics::auc(&test_set.y, &wx)
    };

    // --- 1. key size ---
    println!("\n[ablation 1] Paillier key size (batch 512, {iters} iters)");
    let mut rows = Vec::new();
    for bits in [256usize, 512, 1024] {
        let rep = train(&split, &base.clone().with_key_bits(bits))?;
        rows.push(vec![
            format!("{bits}"),
            format!("{:.2}", rep.comm_mb),
            format!("{:.2}", rep.runtime_secs()),
            format!("{:.3}", auc_of(&rep.full_weights())),
        ]);
    }
    print_table(&["key bits", "comm(MB)", "runtime(s)", "auc"], &rows);

    // --- 2. batch size ---
    println!("\n[ablation 2] mini-batch size (512-bit keys)");
    let mut rows = Vec::new();
    for batch in [128usize, 256, 512, 1024] {
        let cfg = base.clone().with_key_bits(512).with_batch(Some(batch));
        let rep = train(&split, &cfg)?;
        rows.push(vec![
            format!("{batch}"),
            format!("{:.2}", rep.comm_mb),
            format!("{:.2}", rep.runtime_secs()),
            format!("{:.4}", rep.losses.last().unwrap()),
        ]);
    }
    print_table(&["batch", "comm(MB)", "runtime(s)", "final loss"], &rows);

    // --- 3. CP selection (3 parties so rotation matters) ---
    println!("\n[ablation 3] computing-party selection (3 parties)");
    let split3 = split_vertical(&train_set, 3);
    let mut rows = Vec::new();
    for (name, sel) in [("fixed (C,B1)", CpSelection::Fixed), ("rotate", CpSelection::Rotate)] {
        let mut cfg = base.clone().with_key_bits(512);
        cfg.cp_selection = sel;
        let rep = train(&split3, &cfg)?;
        rows.push(vec![
            name.to_string(),
            format!("{:.2}", rep.comm_mb),
            format!("{:.2}", rep.runtime_secs()),
            format!("{:.3}", auc_of(&rep.full_weights())),
        ]);
    }
    print_table(&["cp selection", "comm(MB)", "runtime(s)", "auc"], &rows);

    // --- 4. obfuscator pool ---
    println!("\n[ablation 4] obfuscator pool (512-bit keys)");
    let mut rows = Vec::new();
    for pool in [0usize, 8192] {
        let mut cfg = base.clone().with_key_bits(512);
        cfg.obfuscator_pool = pool;
        let rep = train(&split, &cfg)?;
        rows.push(vec![
            if pool == 0 { "fresh".into() } else { format!("pool {pool}") },
            format!("{:.2}", rep.wall_secs),
            format!("{:.2}", rep.runtime_secs()),
        ]);
    }
    print_table(&["obfuscators", "compute(s)", "runtime(s)"], &rows);
    Ok(())
}
