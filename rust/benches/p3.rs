//! Protocol-3 round bench: one full secure-gradient round on a 3-party
//! in-process mesh, packed (`PackingPolicy::Auto`) vs unpacked (`Off`).
//!
//! Reports wall time, total/ciphertext wire bytes, and the logical
//! ciphertext-exponentiation count per round, plus the packed/unpacked
//! ratios — the numbers persisted to `BENCH_p3.json`. Gradients from the
//! two modes are asserted bit-identical before anything is written.
//! Run with `cargo bench --bench p3`; `EFMVFL_BENCH_FAST=1` shrinks the
//! key/batch for CI smoke runs.

use efmvfl::benchkit::{
    bench_out_dir, cost_split_json, fmt_secs, gate_json, print_table, write_json, Json,
};
use efmvfl::bignum::modular::perf as mont_perf;
use efmvfl::coordinator::testutil::mesh_ctxs_keyed;
use efmvfl::crypto::fixed::PackLayout;
use efmvfl::crypto::he_ops;
use efmvfl::crypto::prng::ChaChaRng;
use efmvfl::linalg::Matrix;
use efmvfl::mpc::ring;
use efmvfl::mpc::share::share_vec;
use efmvfl::net::Transport;
use efmvfl::protocols::{secure_gradient::protocol3_gradients, PackingPolicy};
use std::thread;
use std::time::Instant;

const N_PARTIES: usize = 3;

struct RoundOut {
    grads: Vec<Vec<f64>>,
    wall_secs: f64,
    total_bytes: u64,
    cipher_bytes: u64,
    ct_exps: u64,
    cost: mont_perf::Snapshot,
}

/// One full Protocol 3 round under `policy` on fresh keys/shares.
fn run_round(policy: PackingPolicy, key_bits: usize, m: usize, f: usize, seed: u64) -> RoundOut {
    let mut rng = ChaChaRng::from_seed(seed);
    let blocks: Vec<Matrix> = (0..N_PARTIES)
        .map(|_| Matrix::random(m, f, &mut rng))
        .collect();
    let md: Vec<f64> = (0..m).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
    let (s0, s1) = share_vec(&ring::encode_vec(&md), &mut rng);

    let ctxs = mesh_ctxs_keyed(N_PARTIES, (0, 1), seed, key_bits);
    let stats = ctxs[0].ep.stats().clone();
    he_ops::perf::reset();
    let started = Instant::now();
    let mut handles = Vec::new();
    for (p, mut ctx) in ctxs.into_iter().enumerate() {
        ctx.packing = policy;
        let x = blocks[p].clone();
        let sh = match p {
            0 => Some(s0.clone()),
            1 => Some(s1.clone()),
            _ => None,
        };
        handles.push(thread::spawn(move || {
            protocol3_gradients(&mut ctx, &x, sh.as_ref())
        }));
    }
    let grads: Vec<Vec<f64>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    RoundOut {
        grads,
        wall_secs: started.elapsed().as_secs_f64(),
        total_bytes: stats.total_bytes(),
        cipher_bytes: stats.cipher_bytes(),
        ct_exps: he_ops::perf::ct_exps(),
        // whole-round Montgomery cost split (perf::reset above cleared
        // the modular counters along with ct_exps)
        cost: mont_perf::snapshot(),
    }
}

fn main() {
    let fast = std::env::var("EFMVFL_BENCH_FAST").is_ok();
    let (key_bits, m) = if fast { (1024, 128) } else { (2048, 512) };
    let f = 16;
    let layout = PackLayout::for_modulus_bits(key_bits, m);
    assert!(layout.is_packed(), "{key_bits}-bit keys must give a multi-slot layout");

    let packed = run_round(PackingPolicy::Auto, key_bits, m, f, 7);
    let unpacked = run_round(PackingPolicy::Off, key_bits, m, f, 7);

    // the whole point: same bits, fewer bytes
    for (p, (a, b)) in packed.grads.iter().zip(&unpacked.grads).enumerate() {
        for (j, (ga, gb)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                ga.to_bits(),
                gb.to_bits(),
                "party {p} gradient[{j}] differs: packed {ga} vs unpacked {gb}"
            );
        }
    }

    let ratio = |plain: u64, pk: u64| plain as f64 / pk as f64;
    let cipher_ratio = ratio(unpacked.cipher_bytes, packed.cipher_bytes);
    let exps_ratio = ratio(unpacked.ct_exps, packed.ct_exps);
    let wall_ratio = unpacked.wall_secs / packed.wall_secs;

    let row = |name: &str, r: &RoundOut| {
        vec![
            name.to_string(),
            fmt_secs(r.wall_secs),
            r.cipher_bytes.to_string(),
            r.total_bytes.to_string(),
            r.ct_exps.to_string(),
        ]
    };
    println!("protocol 3 round: {N_PARTIES} parties, {key_bits}b keys, m={m}, f={f}, {} slots/ct", layout.slots);
    print_table(
        &["mode", "wall", "cipher bytes", "total bytes", "ct-exps"],
        &[row("unpacked", &unpacked), row("packed", &packed)],
    );
    println!(
        "ratios (unpacked/packed): cipher bytes {cipher_ratio:.2}x, ct-exps {exps_ratio:.2}x, wall {wall_ratio:.2}x"
    );

    // acceptance floor at full scale; fast mode's narrower key packs
    // fewer slots, so only the direction is checked there
    let floor = if fast { 1.5 } else { 4.0 };
    assert!(cipher_ratio >= floor, "cipher byte ratio {cipher_ratio:.2} below {floor}");
    assert!(exps_ratio >= floor, "ct-exp ratio {exps_ratio:.2} below {floor}");

    // ISSUE 8 acceptance: SOS squaring + the fused signed ladder must
    // cut ≥ 20% of modeled modexp cost units per packed round vs the
    // all-multiplies dual-ladder baseline engine
    let work_over_baseline =
        packed.cost.work as f64 / packed.cost.baseline_work as f64;
    let ceiling = if fast { 0.85 } else { 0.80 };
    println!(
        "packed round modeled work/baseline: {work_over_baseline:.3} \
         ({} sqrs, {} muls, {} allocs)",
        packed.cost.sqrs, packed.cost.muls, packed.cost.allocs
    );
    assert!(
        work_over_baseline <= ceiling,
        "packed round modeled work/baseline {work_over_baseline:.3} above {ceiling}"
    );

    let side = |r: &RoundOut| {
        Json::obj(vec![
            ("wall_secs", Json::Num(r.wall_secs)),
            ("cipher_bytes", Json::Int(r.cipher_bytes)),
            ("total_bytes", Json::Int(r.total_bytes)),
            ("ct_exps", Json::Int(r.ct_exps)),
            ("cost_split", cost_split_json(&r.cost)),
        ])
    };
    let report = Json::obj(vec![
        ("bench", Json::str("p3_round")),
        ("schema_version", Json::Int(1)),
        ("mode", Json::str(if fast { "fast" } else { "full" })),
        ("parties", Json::Int(N_PARTIES as u64)),
        ("key_bits", Json::Int(key_bits as u64)),
        ("batch_rows", Json::Int(m as u64)),
        ("features", Json::Int(f as u64)),
        ("threads", Json::Int(he_ops::he_threads() as u64)),
        ("layout", Json::obj(vec![
            ("slot_bits", Json::Int(layout.slot_bits as u64)),
            ("value_bits", Json::Int(layout.value_bits as u64)),
            ("slots", Json::Int(layout.slots as u64)),
            ("span", Json::Int(layout.span() as u64)),
            ("blocks", Json::Int(layout.blocks_for(m) as u64)),
        ])),
        ("unpacked", side(&unpacked)),
        ("packed", side(&packed)),
        ("ratios", Json::obj(vec![
            ("cipher_bytes", Json::Num(cipher_ratio)),
            ("ct_exps", Json::Num(exps_ratio)),
            ("wall", Json::Num(wall_ratio)),
            ("modexp_work", Json::Num(
                unpacked.cost.work as f64 / packed.cost.work as f64,
            )),
        ])),
        ("gradients_bit_identical", Json::Bool(true)),
        // Regression gates for the EFMVFL_BENCH_FAST=1 CI rerun
        // (1024b/m=128 deterministic counters with ~2% slack); applied
        // by scripts/check_bench_regression.py in perf-trajectory.
        ("ci_gates", Json::Arr(vec![
            gate_json("unpacked.ct_exps", None, Some(8355.0)),
            gate_json("packed.ct_exps", None, Some(2807.0)),
            gate_json("ratios.ct_exps", Some(2.9), None),
            gate_json("packed.cipher_bytes", None, Some(61624.0)),
            gate_json("ratios.cipher_bytes", Some(2.39), None),
            gate_json("packed.cost_split.work_over_baseline", None, Some(0.85)),
            gate_json("gradients_bit_identical", Some(1.0), None),
        ])),
    ]);
    let out = bench_out_dir().join("BENCH_p3.json");
    write_json(&out, &report).expect("write BENCH_p3.json");
    println!("wrote {}", out.display());
}
