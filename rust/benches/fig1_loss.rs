//! **Figure 1 reproduction** — training-loss curves: EFMVFL (red solid
//! in the paper) vs the third-party methods (blue dashed), LR upper
//! panel + PR lower panel.
//!
//! Paper's observation: the curves are "almost identical" — both
//! frameworks compute the same gradients; the only LR difference is that
//! TP-LR's *reported* loss is the Taylor approximation. Ours reports the
//! Taylor loss for both LR variants, so the LR curves should coincide
//! within fixed-point noise, and the PR curves exactly.
//!
//! Emits `out/fig1_lr.csv` and `out/fig1_pr.csv` (iter, efmvfl, tp).

use efmvfl::baselines::Framework;
use efmvfl::benchkit::BenchScale;
use efmvfl::coordinator::TrainConfig;
use efmvfl::data::{csv, split_vertical, synthetic};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let scale = BenchScale::from_env();

    // -- upper panel: LR --
    let mut lr_data = synthetic::credit_default_like(scale.samples.min(10_000), 23, 7);
    lr_data.standardize();
    let lr_split = split_vertical(&lr_data, 2);
    let lr_cfg = TrainConfig::logistic(2)
        .with_key_bits(scale.key_bits)
        .with_iterations(scale.iterations)
        .with_batch(Some(scale.batch))
        .with_seed(7);
    eprintln!("LR curves ...");
    let ours = Framework::Efmvfl.train(&lr_split, &lr_cfg)?;
    let tp = Framework::ThirdParty.train(&lr_split, &lr_cfg)?;
    print_panel("LR (upper panel)", &ours.losses, &tp.losses);
    csv::write_columns(
        Path::new("out/fig1_lr.csv"),
        &["iter", "efmvfl_lr", "tp_lr"],
        &[
            (1..=ours.losses.len()).map(|i| i as f64).collect(),
            ours.losses.clone(),
            tp.losses.clone(),
        ],
    )?;

    // -- lower panel: PR --
    let mut pr_data = synthetic::dvisits_like(scale.samples.min(5_190), 18, 11);
    pr_data.standardize();
    let pr_split = split_vertical(&pr_data, 2);
    let pr_cfg = TrainConfig::poisson(2)
        .with_key_bits(scale.key_bits)
        .with_iterations(scale.iterations)
        .with_batch(Some(scale.batch))
        .with_seed(11);
    eprintln!("PR curves ...");
    let ours = Framework::Efmvfl.train(&pr_split, &pr_cfg)?;
    let tp = Framework::ThirdParty.train(&pr_split, &pr_cfg)?;
    print_panel("PR (lower panel)", &ours.losses, &tp.losses);
    csv::write_columns(
        Path::new("out/fig1_pr.csv"),
        &["iter", "efmvfl_pr", "tp_pr"],
        &[
            (1..=ours.losses.len()).map(|i| i as f64).collect(),
            ours.losses.clone(),
            tp.losses.clone(),
        ],
    )?;

    println!("\nwritten to out/fig1_lr.csv and out/fig1_pr.csv");
    Ok(())
}

fn print_panel(name: &str, ours: &[f64], tp: &[f64]) {
    println!("\nFigure 1 — {name}");
    println!("iter   EFMVFL      TP         |Δ|");
    let mut max_gap = 0.0f64;
    for (i, (a, b)) in ours.iter().zip(tp).enumerate() {
        let gap = (a - b).abs();
        max_gap = max_gap.max(gap);
        println!("{:>4}   {a:.6}   {b:.6}   {gap:.2e}", i + 1);
    }
    println!("max |Δ| = {max_gap:.2e}  (paper: curves 'almost identical')");
}
