//! **Table 1 reproduction** — LR on credit-like data, 2 parties:
//! `auc / ks / comm / runtime` for TP-LR, SS-LR, SS-HE-LR, EFMVFL-LR.
//!
//! Paper's row values (real UCI data, 3 physical servers, CKKS-based
//! TP-LR): TP 0.712/0.371/14.20MB/34.79s · SS 0.719/0.363/181.8MB/71.05s ·
//! SS-HE 0.702/0.367/85.30MB/37.6s · EFMVFL 0.712/0.372/26.45MB/23.29s.
//! Reproduction target is the *shape*: EFMVFL fastest; SS comm ≫ SS-HE
//! comm > EFMVFL comm (see EXPERIMENTS.md for the measured table and the
//! TP-comm caveat — our TP uses Paillier, not packed CKKS).
//!
//! `EFMVFL_BENCH_FAST=1` shrinks the workload; `EFMVFL_PAPER=1` switches
//! to 1024-bit keys.

use efmvfl::baselines::Framework;
use efmvfl::benchkit::{print_table, BenchScale};
use efmvfl::coordinator::TrainConfig;
use efmvfl::data::{csv, split_vertical, synthetic};
use efmvfl::glm::GlmKind;
use efmvfl::{linalg, metrics};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let scale = BenchScale::from_env();
    let mut data = synthetic::credit_default_like(scale.samples, 23, 7);
    data.standardize();
    let mut rng = efmvfl::crypto::prng::ChaChaRng::from_seed(7);
    let (train_set, test_set) = data.train_test_split(0.7, &mut rng);
    let split = split_vertical(&train_set, 2);
    println!(
        "Table 1: LR on {} ({} train / {} test, 23 features, {}-bit keys, batch {}, {} iters)\n",
        data.name, train_set.len(), test_set.len(),
        scale.key_bits, scale.batch, scale.iterations
    );

    let cfg = TrainConfig::logistic(2)
        .with_key_bits(scale.key_bits)
        .with_iterations(scale.iterations)
        .with_batch(Some(scale.batch))
        .with_seed(7);

    let frameworks = [
        Framework::ThirdParty,
        Framework::SecretShare,
        Framework::SsHe,
        Framework::Efmvfl,
    ];
    let mut rows = Vec::new();
    let mut csv_cols: Vec<Vec<f64>> = vec![Vec::new(); 4];
    for fw in frameworks {
        let label = fw.label(GlmKind::Logistic);
        eprintln!("running {label} ...");
        let rep = fw.train(&split, &cfg)?;
        let wx = linalg::gemv(&test_set.x, &rep.full_weights());
        let auc = metrics::auc(&test_set.y, &wx);
        let ks = metrics::ks(&test_set.y, &wx);
        rows.push(vec![
            label,
            format!("{auc:.3}"),
            format!("{ks:.3}"),
            format!("{:.2}mb", rep.comm_mb),
            format!("{:.2}s", rep.runtime_secs()),
        ]);
        csv_cols[0].push(auc);
        csv_cols[1].push(ks);
        csv_cols[2].push(rep.comm_mb);
        csv_cols[3].push(rep.runtime_secs());
    }

    print_table(&["framework", "auc", "ks", "comm", "runtime"], &rows);
    csv::write_columns(
        Path::new("out/table1_lr.csv"),
        &["auc", "ks", "comm_mb", "runtime_s"],
        &csv_cols,
    )?;
    println!("\nwritten to out/table1_lr.csv (rows: TP, SS, SS-HE, EFMVFL)");
    Ok(())
}
