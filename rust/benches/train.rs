//! Training-plane bench: pipelined vs serial wall-clock per Protocol-3
//! iteration on a 3-party mesh.
//!
//! **Serial arm** — cold obfuscator pools: every `r^n` blinding
//! exponentiation runs inline in the online round (the pre-plane
//! behaviour). **Pipelined arm** — before each timed round the pools are
//! refilled to the round's exact demand via the same
//! [`obfuscator_demand`]/`refill_pool` path the offline plane's thread
//! runs, so the online phase pays two multiplications per draw and zero
//! obfuscator exponentiations. The refill happens outside the timer —
//! that is precisely the offline/online split the plane buys on a real
//! deployment, where preprocessing for iteration `t+depth` overlaps
//! iteration `t`'s network wait.
//!
//! Also proves the planes never change the math: gradients from the two
//! arms are asserted bit-identical, and a full mini-batch training run
//! (shuffle on) with the pipeline on vs off must produce bit-identical
//! weights and losses. Results persist to `BENCH_train.json`.
//! Run with `cargo bench --bench train`; `EFMVFL_BENCH_FAST=1` shrinks
//! the key/batch for CI smoke runs.

use efmvfl::benchkit::{
    bench_out_dir, cost_split_json, fmt_secs, gate_json, print_table, write_json, Json,
};
use efmvfl::bignum::modular::perf as mont_perf;
use efmvfl::coordinator::testutil::mesh_ctxs_keyed;
use efmvfl::coordinator::{train, TrainConfig};
use efmvfl::crypto::fixed::PackLayout;
use efmvfl::crypto::prng::ChaChaRng;
use efmvfl::data::{split_vertical, synthetic};
use efmvfl::linalg::Matrix;
use efmvfl::mpc::ring;
use efmvfl::mpc::share::share_vec;
use efmvfl::protocols::plane::{obfuscator_demand, PoolSizing};
use efmvfl::protocols::{secure_gradient::protocol3_gradients, PackingPolicy};
use std::thread;
use std::time::Instant;

const N_PARTIES: usize = 3;
/// Timed Protocol-3 rounds per arm (per-iteration figures are means).
const ROUNDS: usize = 3;

struct ArmOut {
    grads: Vec<Vec<f64>>,
    wall_secs_per_iter: f64,
    /// Online obfuscator exponentiations per round: the full demand when
    /// the pools are cold, zero when the plane prefilled them.
    online_obf_exps: usize,
    /// Montgomery cost split over the timed (online) regions only —
    /// prefill runs outside the counters, like it runs outside the timer.
    online_cost: mont_perf::Snapshot,
}

/// Accumulate a per-round counter delta into an arm total.
fn acc(total: &mut mont_perf::Snapshot, d: &mont_perf::Snapshot) {
    total.sqrs += d.sqrs;
    total.muls += d.muls;
    total.allocs += d.allocs;
    total.work += d.work;
    total.baseline_work += d.baseline_work;
}

/// `ROUNDS` full Protocol 3 rounds on fresh keys/shares; with `prefill`,
/// each round's obfuscator demand is pooled before its timer starts.
fn run_arm(prefill: bool, key_bits: usize, m: usize, f: usize, seed: u64) -> ArmOut {
    let mut rng = ChaChaRng::from_seed(seed);
    let blocks: Vec<Matrix> = (0..N_PARTIES)
        .map(|_| Matrix::random(m, f, &mut rng))
        .collect();
    let md: Vec<f64> = (0..m).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
    let (s0, s1) = share_vec(&ring::encode_vec(&md), &mut rng);

    let mut ctxs = mesh_ctxs_keyed(N_PARTIES, (0, 1), seed, key_bits);
    let pks = ctxs[0].pks.clone();
    // the whole mesh's per-round demand: both CPs' step-1 fanout plus
    // every masked return — what the in-process Shared sizing pools
    let demand = obfuscator_demand(
        0,
        (0, 1),
        m,
        &PoolSizing::Shared { features: vec![f; N_PARTIES] },
        &pks,
        PackingPolicy::Auto,
    );
    let demand_total: usize = demand.iter().map(|&(_, c)| c).sum();
    // same stream the offline plane draws from (party-0 plane seed)
    let mut obf_rng = ChaChaRng::from_seed(seed.wrapping_add(7000));

    let mut wall = 0.0;
    let mut online_cost = mont_perf::Snapshot::default();
    let mut grads: Vec<Vec<f64>> = Vec::new();
    for round in 0..ROUNDS {
        if prefill {
            for &(owner, count) in &demand {
                pks[owner].refill_pool(count, &mut obf_rng);
            }
        }
        let before = mont_perf::snapshot();
        let started = Instant::now();
        let round_grads: Vec<Vec<f64>> = thread::scope(|s| {
            let handles: Vec<_> = ctxs
                .iter_mut()
                .enumerate()
                .map(|(p, ctx)| {
                    let x = &blocks[p];
                    let sh = match p {
                        0 => Some(s0.clone()),
                        1 => Some(s1.clone()),
                        _ => None,
                    };
                    s.spawn(move || protocol3_gradients(ctx, x, sh.as_ref()))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        wall += started.elapsed().as_secs_f64();
        acc(&mut online_cost, &mont_perf::snapshot().delta_since(&before));
        if prefill {
            // the demand model must match the round's draws exactly —
            // a leftover means the plane over-generates (wasted offline
            // work) and would hide an under-prediction elsewhere
            let leftover: usize = pks.iter().map(|pk| pk.pool_len()).sum();
            assert_eq!(leftover, 0, "round {round}: {leftover} pooled obfuscators unused");
        }
        if round == 0 {
            grads = round_grads;
        } else {
            // same inputs each round → same gradients (masks cancel)
            for (a, b) in grads.iter().zip(&round_grads) {
                assert_eq!(a, b, "round {round} gradients drifted");
            }
        }
    }
    ArmOut {
        grads,
        wall_secs_per_iter: wall / ROUNDS as f64,
        online_obf_exps: if prefill { 0 } else { demand_total },
        online_cost,
    }
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("EFMVFL_BENCH_FAST").is_ok();
    let (key_bits, m) = if fast { (1024, 128) } else { (2048, 512) };
    let f = 16;
    let layout = PackLayout::for_modulus_bits(key_bits, m);

    // -- full-train parity: pipeline on/off must not change one bit --
    // (small keys: this checks scheduling, not crypto throughput)
    let mut data = synthetic::credit_default_like(96, 6, 13);
    data.standardize();
    let split = split_vertical(&data, N_PARTIES);
    let base = TrainConfig::logistic(N_PARTIES)
        .with_key_bits(256)
        .with_iterations(6)
        .with_batch(Some(32))
        .with_seed(13);
    eprintln!("train parity (pipeline on vs off) ...");
    let piped = train(&split, &base.clone().with_pipeline(true))?;
    let serial_run = train(&split, &base.clone().with_pipeline(false))?;
    for (p, (a, b)) in piped.weights.iter().zip(&serial_run.weights).enumerate() {
        for (j, (wa, wb)) in a.iter().zip(b).enumerate() {
            assert_eq!(wa.to_bits(), wb.to_bits(), "party {p} weight[{j}] differs");
        }
    }
    for (t, (a, b)) in piped.losses.iter().zip(&serial_run.losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "loss[{t}] differs");
    }

    // -- timed Protocol 3 rounds: cold pools vs plane-prefilled pools --
    eprintln!("serial rounds ({key_bits}b keys, m={m}) ...");
    let serial = run_arm(false, key_bits, m, f, 7);
    eprintln!("pipelined rounds ...");
    let pipelined = run_arm(true, key_bits, m, f, 7);

    for (p, (a, b)) in pipelined.grads.iter().zip(&serial.grads).enumerate() {
        for (j, (ga, gb)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                ga.to_bits(),
                gb.to_bits(),
                "party {p} gradient[{j}] differs: pipelined {ga} vs serial {gb}"
            );
        }
    }

    let wall_ratio = pipelined.wall_secs_per_iter / serial.wall_secs_per_iter;
    let row = |name: &str, a: &ArmOut| {
        vec![
            name.to_string(),
            fmt_secs(a.wall_secs_per_iter),
            a.online_obf_exps.to_string(),
        ]
    };
    println!(
        "protocol 3 iteration: {N_PARTIES} parties, {key_bits}b keys, m={m}, f={f}, {ROUNDS} rounds/arm"
    );
    print_table(
        &["mode", "wall/iter", "online obf-exps"],
        &[row("serial", &serial), row("pipelined", &pipelined)],
    );
    println!("wall ratio (pipelined/serial): {wall_ratio:.2}x");

    // acceptance ceiling at full scale; fast mode's narrower key makes
    // each obfuscator exponentiation ~8x cheaper, so only the direction
    // is checked there
    let ceiling = if fast { 0.95 } else { 0.85 };
    assert!(
        wall_ratio <= ceiling,
        "pipelined/serial wall ratio {wall_ratio:.2} above {ceiling}"
    );

    let side = |a: &ArmOut| {
        Json::obj(vec![
            ("wall_secs_per_iter", Json::Num(a.wall_secs_per_iter)),
            ("online_obfuscator_exps", Json::Int(a.online_obf_exps as u64)),
            ("online_cost_split", cost_split_json(&a.online_cost)),
        ])
    };
    let report = Json::obj(vec![
        ("bench", Json::str("train_planes")),
        ("schema_version", Json::Int(1)),
        ("mode", Json::str(if fast { "fast" } else { "full" })),
        ("parties", Json::Int(N_PARTIES as u64)),
        ("key_bits", Json::Int(key_bits as u64)),
        ("batch_rows", Json::Int(m as u64)),
        ("features", Json::Int(f as u64)),
        ("rounds_per_arm", Json::Int(ROUNDS as u64)),
        ("layout", Json::obj(vec![
            ("slot_bits", Json::Int(layout.slot_bits as u64)),
            ("value_bits", Json::Int(layout.value_bits as u64)),
            ("slots", Json::Int(layout.slots as u64)),
            ("span", Json::Int(layout.span() as u64)),
            ("blocks", Json::Int(layout.blocks_for(m) as u64)),
        ])),
        ("serial", side(&serial)),
        ("pipelined", side(&pipelined)),
        ("ratios", Json::obj(vec![
            ("wall", Json::Num(wall_ratio)),
            ("online_modexp_work", Json::Num(
                pipelined.online_cost.work as f64 / serial.online_cost.work as f64,
            )),
        ])),
        ("gradients_bit_identical", Json::Bool(true)),
        ("train_parity_bit_identical", Json::Bool(true)),
        // Regression gates for the EFMVFL_BENCH_FAST=1 CI rerun
        // (1024b/m=128 deterministic counters with ~2% slack); applied
        // by scripts/check_bench_regression.py in perf-trajectory.
        ("ci_gates", Json::Arr(vec![
            gate_json("serial.online_obfuscator_exps", None, Some(153.0)),
            gate_json("pipelined.online_obfuscator_exps", None, Some(0.0)),
            gate_json(
                "pipelined.online_cost_split.work_over_baseline",
                None,
                Some(0.85),
            ),
            gate_json("gradients_bit_identical", Some(1.0), None),
            gate_json("train_parity_bit_identical", Some(1.0), None),
        ])),
    ]);
    let out = bench_out_dir().join("BENCH_train.json");
    write_json(&out, &report).expect("write BENCH_train.json");
    println!("wrote {}", out.display());
    Ok(())
}
