//! Micro benchmarks over the substrates — the §Perf profiling surface.
//!
//! Covers every hot-path primitive: bignum modpow (with/without the
//! fixed-base table), Paillier enc/dec/ops (pooled and unpooled), the
//! Protocol 3 HE matvec (serial vs threaded, with the speedup ratio),
//! MPC share ops, and native-vs-PJRT dense math.
//! Run with `cargo bench --bench micro`.

use efmvfl::benchkit::{bench_out_dir, fmt_secs, print_table, time_fn, write_json, Json};
use efmvfl::bignum::{BigUint, Montgomery, PowTable};
use efmvfl::crypto::fixed::PackLayout;
use efmvfl::crypto::he_ops;
use efmvfl::crypto::paillier::Keypair;
use efmvfl::crypto::prng::ChaChaRng;
use efmvfl::linalg::{self, Matrix};
use efmvfl::mpc::beaver::TripleDealer;
use efmvfl::mpc::share::share_f64;
use efmvfl::runtime::Compute;

fn main() {
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut add = |name: &str, per_op: f64, note: &str| {
        rows.push(vec![name.to_string(), fmt_secs(per_op), note.to_string()]);
    };

    let mut rng = ChaChaRng::from_seed(99);

    // ---- bignum ----
    for bits in [512usize, 1024, 2048] {
        let mut ml: Vec<u64> = (0..bits / 64).map(|_| rng.next_u64()).collect();
        ml[0] |= 1;
        let m = BigUint::from_limbs(ml);
        let mont = Montgomery::new(&m);
        let base = rng.next_biguint_below(&m);
        let exp = rng.next_biguint_exact_bits(bits);
        let (t, _) = time_fn(0.4, 50, || {
            std::hint::black_box(mont.pow(&base, &exp));
        });
        add(&format!("modpow {bits}b full-exp"), t, "Montgomery 4-bit window");
        let table = PowTable::new(&mont, &base);
        let (t, _) = time_fn(0.3, 200, || {
            std::hint::black_box(table.pow_u64(0xfffff));
        });
        add(&format!("modpow {bits}b 20-bit exp (table)"), t, "Protocol 3 exponent size");
    }

    // ---- Paillier ----
    for bits in [512usize, 1024] {
        let kp = Keypair::generate(bits, &mut rng);
        let (t, _) = time_fn(0.5, 40, || {
            std::hint::black_box(kp.pk.encrypt_i128(123_456, &mut rng));
        });
        add(&format!("paillier-{bits} encrypt"), t, "fresh obfuscator");
        kp.pk.precompute_pool(1000, &mut rng);
        let (t, _) = time_fn(0.3, 200, || {
            std::hint::black_box(kp.pk.encrypt_i128(123_456, &mut rng));
        });
        add(&format!("paillier-{bits} encrypt (pooled)"), t, "§Perf pool optimization");
        let ct = kp.pk.encrypt_i128(7, &mut rng);
        let (t, _) = time_fn(0.4, 40, || {
            std::hint::black_box(kp.sk.decrypt_raw(&ct));
        });
        add(&format!("paillier-{bits} decrypt"), t, "CRT");
        let ct2 = kp.pk.encrypt_i128(8, &mut rng);
        let (t, _) = time_fn(0.2, 500, || {
            std::hint::black_box(kp.pk.add(&ct, &ct2));
        });
        add(&format!("paillier-{bits} ct+ct"), t, "");
        let (t, _) = time_fn(0.3, 100, || {
            std::hint::black_box(kp.pk.mul_plain_i128(&ct, 0xfffff));
        });
        add(&format!("paillier-{bits} ct×20-bit"), t, "matvec inner op");
    }

    // ---- Protocol 3 HE matvec ----
    {
        let kp = Keypair::generate(512, &mut rng);
        let m = 256;
        let x = Matrix::random(m, 12, &mut rng);
        let cts: Vec<_> = (0..m)
            .map(|i| kp.pk.encrypt_i128((i as i128 - 128) << 20, &mut rng))
            .collect();
        let (t, _) = time_fn(2.0, 5, || {
            std::hint::black_box(he_ops::he_matvec_t_threads(&kp.pk, &cts, &x, 1));
        });
        add("he_matvec_t 256×12 (512b)", t, &format!("{} per ct", fmt_secs(t / m as f64)));
    }

    // ---- Protocol 3 HE matvec: serial vs threaded (the tentpole perf
    //      target — per-output-column sharding over scoped threads) ----
    {
        let kp = Keypair::generate(1024, &mut rng);
        let m = 512;
        let f = 16;
        let x = Matrix::random(m, f, &mut rng);
        kp.pk.precompute_pool(m, &mut rng);
        let cts: Vec<_> = (0..m)
            .map(|i| kp.pk.encrypt_i128((i as i128 - 256) << 20, &mut rng))
            .collect();
        let (t_serial, _) = time_fn(5.0, 5, || {
            std::hint::black_box(he_ops::he_matvec_t_threads(&kp.pk, &cts, &x, 1));
        });
        // An explicit EFMVFL_THREADS is honored exactly; otherwise use
        // at least 4 workers (the acceptance shape) even on small boxes,
        // and report the core count so oversubscribed runs read as such.
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let threads = if std::env::var("EFMVFL_THREADS").is_ok() {
            he_ops::he_threads()
        } else {
            he_ops::he_threads().max(4)
        };
        let (t_par, _) = time_fn(5.0, 5, || {
            std::hint::black_box(he_ops::he_matvec_t_threads(&kp.pk, &cts, &x, threads));
        });
        let speedup = t_serial / t_par;
        add("he_matvec_t 512×16 (1024b) serial", t_serial, "1 worker");
        add(
            &format!("he_matvec_t 512×16 (1024b) {threads} workers"),
            t_par,
            &format!("{speedup:.2}x vs serial"),
        );
        println!(
            "he_matvec_t threaded speedup: {speedup:.2}x at {threads} threads \
             ({cores} cores; serial {} vs threaded {})",
            fmt_secs(t_serial),
            fmt_secs(t_par)
        );
    }

    // ---- Protocol 3 ciphertext packing: packed vs unpacked (§Perf) ----
    // The acceptance scale is 2048-bit keys, m=512, f=16;
    // EFMVFL_BENCH_FAST shrinks to 1024-bit / m=128 for CI smoke runs.
    let packing_json;
    {
        let fast = std::env::var("EFMVFL_BENCH_FAST").is_ok();
        let (key_bits, m) = if fast { (1024, 128) } else { (2048usize, 512usize) };
        let f = 16;
        let runs = if fast { 5 } else { 1 };
        let kp = Keypair::generate(key_bits, &mut rng);
        let layout = PackLayout::for_modulus_bits(kp.pk.n.bit_len(), m);
        assert!(layout.is_packed(), "{key_bits}-bit keys must give a multi-slot layout");
        let x = Matrix::random(m, f, &mut rng);
        let share: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();

        let (t_enc_plain, _) = time_fn(3.0, runs, || {
            std::hint::black_box(he_ops::encrypt_share_vec(&kp.pk, &share, &mut rng));
        });
        let (t_enc_packed, _) = time_fn(3.0, runs, || {
            std::hint::black_box(he_ops::pack_encrypt_vec(&kp.pk, &share, &layout, &mut rng));
        });
        let cts_plain = he_ops::encrypt_share_vec(&kp.pk, &share, &mut rng);
        let cts_packed = he_ops::pack_encrypt_vec(&kp.pk, &share, &layout, &mut rng);

        // logical ciphertext exponentiations per matvec (counted once)
        he_ops::perf::reset();
        std::hint::black_box(he_ops::he_matvec_t_threads(&kp.pk, &cts_plain, &x, 1));
        let exps_plain = he_ops::perf::ct_exps();
        he_ops::perf::reset();
        std::hint::black_box(he_ops::packed_matvec_t_threads(&kp.pk, &cts_packed, &x, &layout, 1));
        let exps_packed = he_ops::perf::ct_exps();
        he_ops::perf::reset();

        let (t_mv_plain, _) = time_fn(5.0, runs, || {
            std::hint::black_box(he_ops::he_matvec_t_threads(&kp.pk, &cts_plain, &x, 1));
        });
        let (t_mv_packed, _) = time_fn(5.0, runs, || {
            std::hint::black_box(he_ops::packed_matvec_t_threads(&kp.pk, &cts_packed, &x, &layout, 1));
        });
        let threads = if std::env::var("EFMVFL_THREADS").is_ok() {
            he_ops::he_threads()
        } else {
            he_ops::he_threads().max(4)
        };
        let (t_mv_packed_par, _) = time_fn(5.0, runs, || {
            std::hint::black_box(he_ops::packed_matvec_t_threads(
                &kp.pk, &cts_packed, &x, &layout, threads,
            ));
        });

        // step-1 fanout bytes per CP→party link at this key size
        let ct_bytes = kp.pk.ciphertext_bytes() as u64;
        let fanout_plain = cts_plain.len() as u64 * ct_bytes;
        let fanout_packed = cts_packed.len() as u64 * ct_bytes;

        add(
            &format!("encrypt_share_vec {m} ({key_bits}b)"),
            t_enc_plain,
            &format!("{} cts", cts_plain.len()),
        );
        add(
            &format!("pack_encrypt_vec {m} ({key_bits}b)"),
            t_enc_packed,
            &format!("{} cts, {} slots", cts_packed.len(), layout.slots),
        );
        add(
            &format!("he_matvec_t {m}×{f} ({key_bits}b)"),
            t_mv_plain,
            &format!("{exps_plain} ct-exps"),
        );
        add(
            &format!("packed_matvec_t {m}×{f} ({key_bits}b)"),
            t_mv_packed,
            &format!("{exps_packed} ct-exps"),
        );
        add(
            &format!("packed_matvec_t {m}×{f} ({key_bits}b) {threads} workers"),
            t_mv_packed_par,
            &format!("{:.2}x vs serial", t_mv_packed / t_mv_packed_par),
        );
        println!(
            "packing at {key_bits}b/m={m}/f={f}: {} slots/ct, ct-exps {exps_plain}→{exps_packed} \
             ({:.2}x), fanout {fanout_plain}→{fanout_packed} bytes ({:.2}x)",
            layout.slots,
            exps_plain as f64 / exps_packed as f64,
            fanout_plain as f64 / fanout_packed as f64,
        );

        packing_json = Json::obj(vec![
            ("bench", Json::str("micro")),
            ("schema_version", Json::Int(1)),
            ("mode", Json::str(if fast { "fast" } else { "full" })),
            ("key_bits", Json::Int(key_bits as u64)),
            ("batch_rows", Json::Int(m as u64)),
            ("features", Json::Int(f as u64)),
            ("layout", Json::obj(vec![
                ("slot_bits", Json::Int(layout.slot_bits as u64)),
                ("value_bits", Json::Int(layout.value_bits as u64)),
                ("slots", Json::Int(layout.slots as u64)),
                ("span", Json::Int(layout.span() as u64)),
                ("blocks", Json::Int(layout.blocks_for(m) as u64)),
            ])),
            ("unpacked", Json::obj(vec![
                ("ct_exps", Json::Int(exps_plain)),
                ("fanout_bytes", Json::Int(fanout_plain)),
                ("encrypt_secs", Json::Num(t_enc_plain)),
                ("matvec_secs", Json::Num(t_mv_plain)),
            ])),
            ("packed", Json::obj(vec![
                ("ct_exps", Json::Int(exps_packed)),
                ("fanout_bytes", Json::Int(fanout_packed)),
                ("encrypt_secs", Json::Num(t_enc_packed)),
                ("matvec_secs", Json::Num(t_mv_packed)),
                ("matvec_threaded_secs", Json::Num(t_mv_packed_par)),
                ("threads", Json::Int(threads as u64)),
            ])),
            ("ratios", Json::obj(vec![
                ("ct_exps", Json::Num(exps_plain as f64 / exps_packed as f64)),
                ("fanout_bytes", Json::Num(fanout_plain as f64 / fanout_packed as f64)),
                ("encrypt_secs", Json::Num(t_enc_plain / t_enc_packed)),
                ("serial_over_threaded", Json::Num(t_mv_packed / t_mv_packed_par)),
            ])),
        ]);
        // the acceptance floor holds at full scale (fast mode's narrower
        // key gives fewer slots, so only sanity-check direction there)
        let floor = if fast { 1.5 } else { 4.0 };
        assert!(
            exps_plain as f64 / exps_packed as f64 >= floor,
            "ct-exp ratio below {floor}"
        );
        assert!(
            fanout_plain as f64 / fanout_packed as f64 >= floor,
            "fanout byte ratio below {floor}"
        );
    }

    // ---- MPC ----
    {
        let vals: Vec<f64> = (0..4096).map(|i| i as f64 * 0.25).collect();
        let (t, _) = time_fn(0.2, 200, || {
            std::hint::black_box(share_f64(&vals, &mut rng));
        });
        add("share 4096-vector", t, "Protocol 1 core");
        let mut dealer = TripleDealer::new(5);
        let (t, _) = time_fn(0.2, 200, || {
            std::hint::black_box(dealer.deal(4096));
        });
        add("beaver deal 4096", t, "offline phase");
    }

    // ---- dense math: native vs PJRT ----
    {
        let x = Matrix::random(2048, 24, &mut rng);
        let w: Vec<f64> = (0..24).map(|_| rng.next_gaussian()).collect();
        let (t_native, _) = time_fn(0.3, 200, || {
            std::hint::black_box(linalg::gemv(&x, &w));
        });
        add("gemv 2048×24 native", t_native, "");
        match efmvfl::runtime::backend_by_name("xla") {
            Some(eng) => {
                let (t_xla, _) = time_fn(0.5, 100, || {
                    std::hint::black_box(eng.gemv(&x, &w));
                });
                add(
                    "gemv 2048×24 pjrt",
                    t_xla,
                    &format!("{:.1}× native", t_xla / t_native),
                );
            }
            None => add("gemv 2048×24 pjrt", f64::NAN, "xla feature/artifacts missing"),
        }
    }

    println!();
    print_table(&["operation", "median", "note"], &rows);

    let out = bench_out_dir().join("BENCH_micro.json");
    write_json(&out, &packing_json).expect("write BENCH_micro.json");
    println!("wrote {}", out.display());
}
