//! Micro benchmarks over the substrates — the §Perf profiling surface.
//!
//! Covers every hot-path primitive: bignum modpow (with/without the
//! fixed-base table), Paillier enc/dec/ops (pooled and unpooled), the
//! Protocol 3 HE matvec (serial vs threaded, with the speedup ratio),
//! MPC share ops, and native-vs-PJRT dense math.
//! Run with `cargo bench --bench micro`.

use efmvfl::benchkit::{
    bench_out_dir, cost_split_json, fmt_secs, gate_json, print_table, time_fn, write_json, Json,
};
use efmvfl::bignum::modular::perf as mont_perf;
use efmvfl::bignum::{BigUint, Montgomery, PowTable};
use efmvfl::crypto::fixed::PackLayout;
use efmvfl::crypto::he_ops;
use efmvfl::crypto::paillier::Keypair;
use efmvfl::crypto::prng::ChaChaRng;
use efmvfl::linalg::{self, Matrix};
use efmvfl::mpc::beaver::TripleDealer;
use efmvfl::mpc::share::share_f64;
use efmvfl::runtime::Compute;

fn main() {
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut add = |name: &str, per_op: f64, note: &str| {
        rows.push(vec![name.to_string(), fmt_secs(per_op), note.to_string()]);
    };

    let mut rng = ChaChaRng::from_seed(99);

    // ---- bignum ----
    for bits in [512usize, 1024, 2048] {
        let mut ml: Vec<u64> = (0..bits / 64).map(|_| rng.next_u64()).collect();
        ml[0] |= 1;
        let m = BigUint::from_limbs(ml);
        let mont = Montgomery::new(&m);
        let base = rng.next_biguint_below(&m);
        let exp = rng.next_biguint_exact_bits(bits);
        let (t, _) = time_fn(0.4, 50, || {
            std::hint::black_box(mont.pow(&base, &exp));
        });
        add(&format!("modpow {bits}b full-exp"), t, "Montgomery 4-bit window");
        let table = PowTable::new(&mont, &base);
        let (t, _) = time_fn(0.3, 200, || {
            std::hint::black_box(table.pow_u64(0xfffff));
        });
        add(&format!("modpow {bits}b 20-bit exp (table)"), t, "Protocol 3 exponent size");
    }

    // ---- bignum: dedicated SOS squaring vs CIOS multiply (§Perf) ----
    // The 4-bit-window ladder does ~4 squarings per window multiply, so
    // the 3k²-vs-4k² limb-product gap compounds through every modexp.
    let sqr_mul_json;
    {
        let mut entries = Vec::new();
        for bits in [1024usize, 2048, 4096] {
            let mut ml: Vec<u64> = (0..bits / 64).map(|_| rng.next_u64()).collect();
            ml[0] |= 1;
            let m = BigUint::from_limbs(ml);
            let mont = Montgomery::new(&m);
            let a = mont.enter_mont(&rng.next_biguint_below(&m));
            let (t_mul, _) = time_fn(0.3, 400, || {
                std::hint::black_box(mont.mul_mont(&a, &a));
            });
            let (t_sqr, _) = time_fn(0.3, 400, || {
                std::hint::black_box(mont.mont_sqr_raw(&a));
            });
            let k = mont.limb_count();
            let modeled = mont_perf::sqr_work(k) as f64 / mont_perf::mul_work(k) as f64;
            add(
                &format!("mont_sqr {bits}b"),
                t_sqr,
                &format!("{:.2}x of mul (model {modeled:.2})", t_sqr / t_mul),
            );
            entries.push(Json::obj(vec![
                ("bits", Json::Int(bits as u64)),
                ("mul_secs", Json::Num(t_mul)),
                ("sqr_secs", Json::Num(t_sqr)),
                ("measured_ratio", Json::Num(t_sqr / t_mul)),
                ("modeled_ratio", Json::Num(modeled)),
            ]));
        }
        sqr_mul_json = Json::Arr(entries);
    }

    // ---- bignum: interleaved multi-exponentiation vs per-term pows ----
    // Straus/Shamir shares one squaring ladder across all bases; the
    // win over independent pows grows with the number of riding terms.
    let interleave_json;
    {
        let bits = 2048usize;
        let mut ml: Vec<u64> = (0..bits / 64).map(|_| rng.next_u64()).collect();
        ml[0] |= 1;
        let m = BigUint::from_limbs(ml);
        let mont = Montgomery::new(&m);
        let mut entries = Vec::new();
        for terms in [4usize, 32] {
            let bases: Vec<BigUint> =
                (0..terms).map(|_| rng.next_biguint_below(&m)).collect();
            let exps: Vec<BigUint> =
                (0..terms).map(|_| rng.next_biguint_exact_bits(20)).collect();
            let per_term = |bases: &[BigUint], exps: &[BigUint]| {
                let mut acc = BigUint::one();
                for (b, e) in bases.iter().zip(exps) {
                    acc = acc.mul_mod(&mont.pow(b, e), &m);
                }
                acc
            };
            // deterministic op counts: one evaluation of each strategy
            mont_perf::reset();
            let got = mont.multi_pow(&bases, &exps);
            let c_inter = mont_perf::snapshot();
            mont_perf::reset();
            let want = per_term(&bases, &exps);
            let c_per = mont_perf::snapshot();
            assert_eq!(got, want, "multi_pow disagrees with per-term product");
            let (t_inter, _) = time_fn(0.4, 100, || {
                std::hint::black_box(mont.multi_pow(&bases, &exps));
            });
            let (t_per, _) = time_fn(0.4, 100, || {
                std::hint::black_box(per_term(&bases, &exps));
            });
            add(
                &format!("multi_pow {terms}×20-bit ({bits}b)"),
                t_inter,
                &format!("{:.2}x vs per-term pows", t_per / t_inter),
            );
            entries.push(Json::obj(vec![
                ("terms", Json::Int(terms as u64)),
                ("exp_bits", Json::Int(20)),
                ("interleaved_secs", Json::Num(t_inter)),
                ("per_term_secs", Json::Num(t_per)),
                ("interleaved_cost", cost_split_json(&c_inter)),
                ("per_term_cost", cost_split_json(&c_per)),
                (
                    "work_ratio_per_term_over_interleaved",
                    Json::Num(c_per.work as f64 / c_inter.work as f64),
                ),
            ]));
        }
        interleave_json = Json::Arr(entries);
    }

    // ---- Paillier ----
    for bits in [512usize, 1024] {
        let kp = Keypair::generate(bits, &mut rng);
        let (t, _) = time_fn(0.5, 40, || {
            std::hint::black_box(kp.pk.encrypt_i128(123_456, &mut rng));
        });
        add(&format!("paillier-{bits} encrypt"), t, "fresh obfuscator");
        kp.pk.precompute_pool(1000, &mut rng);
        let (t, _) = time_fn(0.3, 200, || {
            std::hint::black_box(kp.pk.encrypt_i128(123_456, &mut rng));
        });
        add(&format!("paillier-{bits} encrypt (pooled)"), t, "§Perf pool optimization");
        let ct = kp.pk.encrypt_i128(7, &mut rng);
        let (t, _) = time_fn(0.4, 40, || {
            std::hint::black_box(kp.sk.decrypt_raw(&ct));
        });
        add(&format!("paillier-{bits} decrypt"), t, "CRT");
        let ct2 = kp.pk.encrypt_i128(8, &mut rng);
        let (t, _) = time_fn(0.2, 500, || {
            std::hint::black_box(kp.pk.add(&ct, &ct2));
        });
        add(&format!("paillier-{bits} ct+ct"), t, "");
        let (t, _) = time_fn(0.3, 100, || {
            std::hint::black_box(kp.pk.mul_plain_i128(&ct, 0xfffff));
        });
        add(&format!("paillier-{bits} ct×20-bit"), t, "matvec inner op");
    }

    // ---- Protocol 3 HE matvec ----
    {
        let kp = Keypair::generate(512, &mut rng);
        let m = 256;
        let x = Matrix::random(m, 12, &mut rng);
        let cts: Vec<_> = (0..m)
            .map(|i| kp.pk.encrypt_i128((i as i128 - 128) << 20, &mut rng))
            .collect();
        let (t, _) = time_fn(2.0, 5, || {
            std::hint::black_box(he_ops::he_matvec_t_threads(&kp.pk, &cts, &x, 1));
        });
        add("he_matvec_t 256×12 (512b)", t, &format!("{} per ct", fmt_secs(t / m as f64)));
    }

    // ---- Protocol 3 HE matvec: serial vs threaded (the tentpole perf
    //      target — per-output-column sharding over scoped threads) ----
    {
        let kp = Keypair::generate(1024, &mut rng);
        let m = 512;
        let f = 16;
        let x = Matrix::random(m, f, &mut rng);
        kp.pk.precompute_pool(m, &mut rng);
        let cts: Vec<_> = (0..m)
            .map(|i| kp.pk.encrypt_i128((i as i128 - 256) << 20, &mut rng))
            .collect();
        let (t_serial, _) = time_fn(5.0, 5, || {
            std::hint::black_box(he_ops::he_matvec_t_threads(&kp.pk, &cts, &x, 1));
        });
        // An explicit EFMVFL_THREADS is honored exactly; otherwise use
        // at least 4 workers (the acceptance shape) even on small boxes,
        // and report the core count so oversubscribed runs read as such.
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let threads = if std::env::var("EFMVFL_THREADS").is_ok() {
            he_ops::he_threads()
        } else {
            he_ops::he_threads().max(4)
        };
        let (t_par, _) = time_fn(5.0, 5, || {
            std::hint::black_box(he_ops::he_matvec_t_threads(&kp.pk, &cts, &x, threads));
        });
        let speedup = t_serial / t_par;
        add("he_matvec_t 512×16 (1024b) serial", t_serial, "1 worker");
        add(
            &format!("he_matvec_t 512×16 (1024b) {threads} workers"),
            t_par,
            &format!("{speedup:.2}x vs serial"),
        );
        println!(
            "he_matvec_t threaded speedup: {speedup:.2}x at {threads} threads \
             ({cores} cores; serial {} vs threaded {})",
            fmt_secs(t_serial),
            fmt_secs(t_par)
        );
    }

    // ---- Protocol 3 ciphertext packing: packed vs unpacked (§Perf) ----
    // The acceptance scale is 2048-bit keys, m=512, f=16;
    // EFMVFL_BENCH_FAST shrinks to 1024-bit / m=128 for CI smoke runs.
    let packing_json;
    {
        let fast = std::env::var("EFMVFL_BENCH_FAST").is_ok();
        let (key_bits, m) = if fast { (1024, 128) } else { (2048usize, 512usize) };
        let f = 16;
        let runs = if fast { 5 } else { 1 };
        let kp = Keypair::generate(key_bits, &mut rng);
        let layout = PackLayout::for_modulus_bits(kp.pk.n.bit_len(), m);
        assert!(layout.is_packed(), "{key_bits}-bit keys must give a multi-slot layout");
        let x = Matrix::random(m, f, &mut rng);
        let share: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();

        let (t_enc_plain, _) = time_fn(3.0, runs, || {
            std::hint::black_box(he_ops::encrypt_share_vec(&kp.pk, &share, &mut rng));
        });
        let (t_enc_packed, _) = time_fn(3.0, runs, || {
            std::hint::black_box(he_ops::pack_encrypt_vec(&kp.pk, &share, &layout, &mut rng));
        });
        let cts_plain = he_ops::encrypt_share_vec(&kp.pk, &share, &mut rng);
        let cts_packed = he_ops::pack_encrypt_vec(&kp.pk, &share, &layout, &mut rng);

        // logical ciphertext exponentiations and the Montgomery cost
        // split per matvec (counted once; perf::reset clears both)
        he_ops::perf::reset();
        std::hint::black_box(he_ops::he_matvec_t_threads(&kp.pk, &cts_plain, &x, 1));
        let exps_plain = he_ops::perf::ct_exps();
        let cost_plain = mont_perf::snapshot();
        he_ops::perf::reset();
        std::hint::black_box(he_ops::packed_matvec_t_threads(&kp.pk, &cts_packed, &x, &layout, 1));
        let exps_packed = he_ops::perf::ct_exps();
        let cost_packed = mont_perf::snapshot();
        he_ops::perf::reset();

        let (t_mv_plain, _) = time_fn(5.0, runs, || {
            std::hint::black_box(he_ops::he_matvec_t_threads(&kp.pk, &cts_plain, &x, 1));
        });
        let (t_mv_packed, _) = time_fn(5.0, runs, || {
            std::hint::black_box(he_ops::packed_matvec_t_threads(&kp.pk, &cts_packed, &x, &layout, 1));
        });
        let threads = if std::env::var("EFMVFL_THREADS").is_ok() {
            he_ops::he_threads()
        } else {
            he_ops::he_threads().max(4)
        };
        let (t_mv_packed_par, _) = time_fn(5.0, runs, || {
            std::hint::black_box(he_ops::packed_matvec_t_threads(
                &kp.pk, &cts_packed, &x, &layout, threads,
            ));
        });

        // step-1 fanout bytes per CP→party link at this key size
        let ct_bytes = kp.pk.ciphertext_bytes() as u64;
        let fanout_plain = cts_plain.len() as u64 * ct_bytes;
        let fanout_packed = cts_packed.len() as u64 * ct_bytes;

        add(
            &format!("encrypt_share_vec {m} ({key_bits}b)"),
            t_enc_plain,
            &format!("{} cts", cts_plain.len()),
        );
        add(
            &format!("pack_encrypt_vec {m} ({key_bits}b)"),
            t_enc_packed,
            &format!("{} cts, {} slots", cts_packed.len(), layout.slots),
        );
        add(
            &format!("he_matvec_t {m}×{f} ({key_bits}b)"),
            t_mv_plain,
            &format!("{exps_plain} ct-exps"),
        );
        add(
            &format!("packed_matvec_t {m}×{f} ({key_bits}b)"),
            t_mv_packed,
            &format!("{exps_packed} ct-exps"),
        );
        add(
            &format!("packed_matvec_t {m}×{f} ({key_bits}b) {threads} workers"),
            t_mv_packed_par,
            &format!("{:.2}x vs serial", t_mv_packed / t_mv_packed_par),
        );
        println!(
            "packing at {key_bits}b/m={m}/f={f}: {} slots/ct, ct-exps {exps_plain}→{exps_packed} \
             ({:.2}x), fanout {fanout_plain}→{fanout_packed} bytes ({:.2}x)",
            layout.slots,
            exps_plain as f64 / exps_packed as f64,
            fanout_plain as f64 / fanout_packed as f64,
        );

        packing_json = Json::obj(vec![
            ("bench", Json::str("micro")),
            ("schema_version", Json::Int(1)),
            ("mode", Json::str(if fast { "fast" } else { "full" })),
            ("key_bits", Json::Int(key_bits as u64)),
            ("batch_rows", Json::Int(m as u64)),
            ("features", Json::Int(f as u64)),
            ("layout", Json::obj(vec![
                ("slot_bits", Json::Int(layout.slot_bits as u64)),
                ("value_bits", Json::Int(layout.value_bits as u64)),
                ("slots", Json::Int(layout.slots as u64)),
                ("span", Json::Int(layout.span() as u64)),
                ("blocks", Json::Int(layout.blocks_for(m) as u64)),
            ])),
            ("unpacked", Json::obj(vec![
                ("ct_exps", Json::Int(exps_plain)),
                ("fanout_bytes", Json::Int(fanout_plain)),
                ("encrypt_secs", Json::Num(t_enc_plain)),
                ("matvec_secs", Json::Num(t_mv_plain)),
                ("cost_split", cost_split_json(&cost_plain)),
            ])),
            ("packed", Json::obj(vec![
                ("ct_exps", Json::Int(exps_packed)),
                ("fanout_bytes", Json::Int(fanout_packed)),
                ("encrypt_secs", Json::Num(t_enc_packed)),
                ("matvec_secs", Json::Num(t_mv_packed)),
                ("matvec_threaded_secs", Json::Num(t_mv_packed_par)),
                ("threads", Json::Int(threads as u64)),
                ("cost_split", cost_split_json(&cost_packed)),
            ])),
            ("ratios", Json::obj(vec![
                ("ct_exps", Json::Num(exps_plain as f64 / exps_packed as f64)),
                ("fanout_bytes", Json::Num(fanout_plain as f64 / fanout_packed as f64)),
                ("encrypt_secs", Json::Num(t_enc_plain / t_enc_packed)),
                ("serial_over_threaded", Json::Num(t_mv_packed / t_mv_packed_par)),
                ("modexp_work", Json::Num(cost_plain.work as f64 / cost_packed.work as f64)),
            ])),
        ]);
        // the acceptance floor holds at full scale (fast mode's narrower
        // key gives fewer slots, so only sanity-check direction there)
        let floor = if fast { 1.5 } else { 4.0 };
        assert!(
            exps_plain as f64 / exps_packed as f64 >= floor,
            "ct-exp ratio below {floor}"
        );
        assert!(
            fanout_plain as f64 / fanout_packed as f64 >= floor,
            "fanout byte ratio below {floor}"
        );
        // SOS squaring + the fused signed ladder must price the packed
        // matvec well under the all-multiplies dual-ladder baseline
        assert!(
            (cost_packed.work as f64) <= 0.85 * cost_packed.baseline_work as f64,
            "packed matvec modeled work/baseline above 0.85 \
             ({} / {})",
            cost_packed.work,
            cost_packed.baseline_work,
        );
    }

    // ---- MPC ----
    {
        let vals: Vec<f64> = (0..4096).map(|i| i as f64 * 0.25).collect();
        let (t, _) = time_fn(0.2, 200, || {
            std::hint::black_box(share_f64(&vals, &mut rng));
        });
        add("share 4096-vector", t, "Protocol 1 core");
        let mut dealer = TripleDealer::new(5);
        let (t, _) = time_fn(0.2, 200, || {
            std::hint::black_box(dealer.deal(4096));
        });
        add("beaver deal 4096", t, "offline phase");
    }

    // ---- dense math: native vs PJRT ----
    {
        let x = Matrix::random(2048, 24, &mut rng);
        let w: Vec<f64> = (0..24).map(|_| rng.next_gaussian()).collect();
        let (t_native, _) = time_fn(0.3, 200, || {
            std::hint::black_box(linalg::gemv(&x, &w));
        });
        add("gemv 2048×24 native", t_native, "");
        match efmvfl::runtime::backend_by_name("xla") {
            Some(eng) => {
                let (t_xla, _) = time_fn(0.5, 100, || {
                    std::hint::black_box(eng.gemv(&x, &w));
                });
                add(
                    "gemv 2048×24 pjrt",
                    t_xla,
                    &format!("{:.1}× native", t_xla / t_native),
                );
            }
            None => add("gemv 2048×24 pjrt", f64::NAN, "xla feature/artifacts missing"),
        }
    }

    println!();
    print_table(&["operation", "median", "note"], &rows);

    // Compose the persisted report: the packing section plus the new
    // squaring/interleaving sections and the CI regression gates.
    // Gate bounds are fast-scale (1024b/m=128) deterministic counters
    // with ~2% slack — scripts/check_bench_regression.py applies them
    // to the EFMVFL_BENCH_FAST=1 rerun in the perf-trajectory job.
    let micro_json = match packing_json {
        Json::Obj(mut fields) => {
            fields.push(("sqr_vs_mul".to_string(), sqr_mul_json));
            fields.push(("interleaved_vs_per_term".to_string(), interleave_json));
            fields.push((
                "ci_gates".to_string(),
                Json::Arr(vec![
                    gate_json("unpacked.ct_exps", None, Some(2089.0)),
                    gate_json("packed.ct_exps", None, Some(702.0)),
                    gate_json("ratios.ct_exps", Some(2.9), None),
                    gate_json("ratios.fanout_bytes", Some(2.39), None),
                    gate_json("packed.cost_split.work_over_baseline", None, Some(0.85)),
                    gate_json("sqr_vs_mul.0.modeled_ratio", None, Some(0.76)),
                    gate_json(
                        "interleaved_vs_per_term.1.work_ratio_per_term_over_interleaved",
                        Some(1.2),
                        None,
                    ),
                ]),
            ));
            Json::Obj(fields)
        }
        other => other,
    };
    let out = bench_out_dir().join("BENCH_micro.json");
    write_json(&out, &micro_json).expect("write BENCH_micro.json");
    println!("wrote {}", out.display());
}
