//! **Figure 2 reproduction** — EFMVFL-LR runtime (upper) and
//! communication (lower) as the number of participants grows, host B1's
//! data replicated to new parties (paper §5.1).
//!
//! Paper's shape targets:
//! - comm grows **linearly** in the party count (lower panel's fitted
//!   line) — we fit a line and report R²;
//! - runtime **jumps** from 2 → 3 parties (non-CP parties do 2 cipher
//!   products instead of 1 — Algorithm 1) then flattens.
//!
//! Emits `out/fig2_scaling.csv` (parties, comm_mb, runtime_s).

use efmvfl::benchkit::{print_table, BenchScale};
use efmvfl::coordinator::{train, TrainConfig};
use efmvfl::data::{csv, split_vertical, synthetic};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let scale = BenchScale::from_env();
    let samples = scale.samples.min(10_000);
    let mut data = synthetic::credit_default_like(samples, 16, 7);
    data.standardize();
    let base = split_vertical(&data, 2);
    println!(
        "Figure 2: EFMVFL-LR scaling, {} samples, batch {}, {} iters, {}-bit keys\n",
        samples, scale.batch, scale.iterations, scale.key_bits
    );

    let max_parties = 6usize;
    let mut rows = Vec::new();
    let (mut parties_col, mut comm_col, mut rt_col) = (Vec::new(), Vec::new(), Vec::new());
    for parties in 2..=max_parties {
        let split = base.replicate_hosts(parties - 1);
        let cfg = TrainConfig::logistic(parties)
            .with_key_bits(scale.key_bits)
            .with_iterations(scale.iterations)
            .with_batch(Some(scale.batch))
            .with_seed(7);
        eprintln!("{parties} parties ...");
        let rep = train(&split, &cfg)?;
        rows.push(vec![
            parties.to_string(),
            format!("{:.2}", rep.comm_mb),
            format!("{:.2}", rep.runtime_secs()),
        ]);
        parties_col.push(parties as f64);
        comm_col.push(rep.comm_mb);
        rt_col.push(rep.runtime_secs());
    }
    print_table(&["parties", "comm(MB)", "runtime(s)"], &rows);

    // linear fit for the comm panel (paper fits a straight line)
    let (slope, intercept, r2) = linfit(&parties_col, &comm_col);
    println!("\ncomm fit: {slope:.2}·k + {intercept:.2} MB,  R² = {r2:.4}  (paper: linear)");
    let jump = rt_col[1] / rt_col[0];
    let tail_flat = rt_col.last().unwrap() / rt_col[1];
    println!(
        "runtime 2→3 parties: ×{jump:.2} jump; 3→{max_parties} parties: ×{tail_flat:.2} \
         (paper: sudden increase then flattens)"
    );

    csv::write_columns(
        Path::new("out/fig2_scaling.csv"),
        &["parties", "comm_mb", "runtime_s"],
        &[parties_col, comm_col, rt_col],
    )?;
    println!("written to out/fig2_scaling.csv");
    Ok(())
}

/// Least-squares line fit returning (slope, intercept, R²).
fn linfit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    let n = x.len() as f64;
    let (sx, sy): (f64, f64) = (x.iter().sum(), y.iter().sum());
    let sxx: f64 = x.iter().map(|v| v * v).sum();
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| a * b).sum();
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let intercept = (sy - slope * sx) / n;
    let mean_y = sy / n;
    let ss_tot: f64 = y.iter().map(|v| (v - mean_y) * (v - mean_y)).sum();
    let ss_res: f64 = x
        .iter()
        .zip(y)
        .map(|(a, b)| {
            let p = slope * a + intercept;
            (b - p) * (b - p)
        })
        .sum();
    (slope, intercept, 1.0 - ss_res / ss_tot)
}
