#!/usr/bin/env bash
# Telemetry smoke: a traced 3-party training run validated record by
# record, then a 3-party serve mesh exposing a live Prometheus /metrics
# endpoint that is scraped mid-run. Used by CI (tier-1 job) and runnable
# locally: scripts/ci_obs_smoke.sh [path/to/efmvfl]
set -euo pipefail

BIN="${1:-target/release/efmvfl}"
OUT="$(mktemp -d)"
trap 'rm -rf "$OUT"' EXIT

echo "== traced 3-party training run =="
"$BIN" train --parties 3 --samples 400 --features 8 --iters 3 --key-bits 256 \
    --batch 128 --trace-dir "$OUT/trace" --save "$OUT/model.efmv"
python3 scripts/check_trace.py "$OUT/trace" --parties 3 --iters 3 --require-wire
"$BIN" report --trace-dir "$OUT/trace"

echo "== traced 3-party distributed run (real TCP) + fused critical path =="
cat > "$OUT/dist.toml" <<'EOF'
model = "lr"
seed = 11
iterations = 3
key_bits = 256
batch_size = 64
[roster]
0 = "127.0.0.1:7310"
1 = "127.0.0.1:7311"
2 = "127.0.0.1:7312"
EOF
"$BIN" run-distributed --config "$OUT/dist.toml" --samples 300 --features 6 \
    --trace-dir "$OUT/dtrace"
# every recv must link to its sender's span, clocks aligned, wire events present
python3 scripts/check_trace.py "$OUT/dtrace" --parties 3 --iters 3 --require-wire
# fused causal DAG: the report must name a bottleneck for each iteration
"$BIN" report --trace-dir "$OUT/dtrace" --critical-path | tee "$OUT/critical.txt"
grep -q "bottleneck:" "$OUT/critical.txt"
# Perfetto export: valid Chrome trace-event JSON with paired flows
"$BIN" report --trace-dir "$OUT/dtrace" --perfetto "$OUT/dtrace.json"
python3 scripts/check_trace.py --perfetto "$OUT/dtrace.json"

echo "== serve mesh with a live /metrics endpoint =="
cat > "$OUT/serve.toml" <<'EOF'
model = "lr"
seed = 7
[roster]
0 = "127.0.0.1:7300"
1 = "127.0.0.1:7301"
2 = "127.0.0.1:7302"
[serve]
gateway = "127.0.0.1:8300"
max_batch = 8
max_wait_ms = 5
max_requests = 60
[obs]
metrics_addr = "127.0.0.1:9300"
EOF

PIDS=()
for id in 0 1 2; do
    "$BIN" serve --config "$OUT/serve.toml" --id "$id" --load "$OUT/model.efmv" \
        --samples 200 &
    PIDS+=("$!")
done

# wait for the gateway's client port to come up
python3 - <<'EOF'
import socket, sys, time
for _ in range(150):
    try:
        socket.create_connection(("127.0.0.1", 8300), timeout=0.5).close()
        sys.exit(0)
    except OSError:
        time.sleep(0.2)
sys.exit("gateway never came up on 127.0.0.1:8300")
EOF

# first load wave populates the live registry, then scrape /metrics
# while the mesh is still serving, then drain the request budget
"$BIN" loadgen --gateway 127.0.0.1:8300 --requests 50 --clients 3 --max-id 200
python3 scripts/check_trace.py --metrics http://127.0.0.1:9300/metrics --require-samples
"$BIN" loadgen --gateway 127.0.0.1:8300 --requests 10 --clients 2 --max-id 200

for pid in "${PIDS[@]}"; do
    wait "$pid"
done
echo "== telemetry smoke passed =="
