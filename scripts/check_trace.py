#!/usr/bin/env python3
"""Validate efmvfl telemetry: trace JSONL directories and /metrics text.

Trace mode::

    check_trace.py TRACE_DIR --parties N [--iters N]

Checks every ``party-*.jsonl`` file written by ``--trace-dir``:

- every line is a flat JSON object of scalars (the trace schema) with a
  string ``kind`` and an integer ``party`` matching the file name;
- span records carry ``stage``/``t``/``wall_s`` plus the HE counter
  fields (``ct_exps``, ``mont_sqrs``, ``mont_muls``, ``mont_work``);
- for every iteration a party traced, all four pipeline stages appear,
  with at least one protocol round span (``stage == "proto"``);
- with ``--iters N``, the traced iterations are exactly ``0..N-1``.

Metrics mode::

    check_trace.py --metrics URL [--require-samples]

Scrapes the URL once and parses the body as Prometheus text exposition
(comment lines, or ``name[{labels}] value`` samples);
``--require-samples`` additionally demands at least one ``efmvfl_``
sample line.
"""

import argparse
import json
import re
import sys
import urllib.request

PIPELINE_STAGES = ["prepare", "mask_encrypt", "exchange", "combine"]
COUNTER_FIELDS = ["ct_exps", "mont_sqrs", "mont_muls", "mont_work"]
SAMPLE_RE = re.compile(r"^[A-Za-z_:][A-Za-z0-9_:]*(\{[^{}]*\})?$")


def fail(msg):
    sys.exit(f"check_trace: FAIL: {msg}")


def check_record(where, rec):
    """Schema-check one parsed JSONL record; return (kind, party)."""
    if not isinstance(rec, dict):
        fail(f"{where}: record is not a JSON object")
    for key, value in rec.items():
        if isinstance(value, (dict, list)):
            fail(f"{where}: field {key!r} is not a scalar")
    kind = rec.get("kind")
    if not isinstance(kind, str) or not kind:
        fail(f"{where}: missing or non-string 'kind'")
    party = rec.get("party")
    if not isinstance(party, int) or party < 0:
        fail(f"{where}: missing or bad 'party'")
    if kind == "span":
        stage = rec.get("stage")
        if not isinstance(stage, str) or not stage:
            fail(f"{where}: span without a 'stage'")
        t = rec.get("t")
        if not isinstance(t, int) or t < 0:
            fail(f"{where}: span without an iteration 't'")
        wall = rec.get("wall_s")
        if not isinstance(wall, (int, float)) or wall < 0:
            fail(f"{where}: span without a non-negative 'wall_s'")
        for field in COUNTER_FIELDS:
            v = rec.get(field)
            if not isinstance(v, int) or v < 0:
                fail(f"{where}: span without counter {field!r}")
        if stage == "proto" and not isinstance(rec.get("proto"), str):
            fail(f"{where}: protocol span without a 'proto' tag")
    elif kind == "net":
        for field in ("from", "to", "bytes", "msgs"):
            v = rec.get(field)
            if not isinstance(v, int) or v < 0:
                fail(f"{where}: net event without {field!r}")
    return kind, party


def check_trace_dir(trace_dir, parties, iters):
    import pathlib

    root = pathlib.Path(trace_dir)
    records = 0
    for party in range(parties):
        path = root / f"party-{party}.jsonl"
        if not path.is_file():
            fail(f"missing trace file {path}")
        # (stage, t) pairs and the iterations with a protocol round
        stage_cover = set()
        proto_rounds = set()
        iterations = set()
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            where = f"{path}:{lineno}"
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                fail(f"{where}: not JSON: {e}")
            kind, rec_party = check_record(where, rec)
            if rec_party != party:
                fail(f"{where}: party {rec_party} record in party {party}'s file")
            records += 1
            if kind == "span":
                t = rec["t"]
                stage_cover.add((rec["stage"], t))
                iterations.add(t)
                if rec["stage"] == "proto":
                    proto_rounds.add(t)
        if not iterations:
            fail(f"{path}: no spans at all")
        if iters is not None and iterations != set(range(iters)):
            fail(f"{path}: traced iterations {sorted(iterations)}, expected 0..{iters - 1}")
        for t in sorted(iterations):
            for stage in PIPELINE_STAGES:
                if (stage, t) not in stage_cover:
                    fail(f"{path}: no {stage!r} span for iteration {t}")
            if t not in proto_rounds:
                fail(f"{path}: no protocol round span for iteration {t}")
    print(f"check_trace: OK: {records} records, {parties} parties, "
          f"all {len(PIPELINE_STAGES)} stages covered")


def check_metrics(url, require_samples):
    with urllib.request.urlopen(url, timeout=10) as resp:
        body = resp.read().decode("utf-8")
    samples = 0
    for lineno, line in enumerate(body.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        parts = line.rsplit(None, 1)
        if len(parts) != 2:
            fail(f"metrics line {lineno}: not 'name value': {line!r}")
        name, value = parts
        if not SAMPLE_RE.match(name):
            fail(f"metrics line {lineno}: bad metric name {name!r}")
        try:
            float(value)
        except ValueError:
            fail(f"metrics line {lineno}: bad sample value {value!r}")
        samples += 1
    if require_samples and not any(
        l.startswith("efmvfl_") for l in body.splitlines()
    ):
        fail(f"no efmvfl_ samples scraped from {url}")
    print(f"check_trace: OK: {samples} Prometheus samples from {url}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace_dir", nargs="?", help="directory written by --trace-dir")
    ap.add_argument("--parties", type=int, default=3)
    ap.add_argument("--iters", type=int, help="require iterations 0..N-1 exactly")
    ap.add_argument("--metrics", help="scrape and parse this /metrics URL")
    ap.add_argument("--require-samples", action="store_true",
                    help="with --metrics: demand at least one efmvfl_ sample")
    args = ap.parse_args()
    if not args.trace_dir and not args.metrics:
        ap.error("give a TRACE_DIR, --metrics URL, or both")
    if args.trace_dir:
        check_trace_dir(args.trace_dir, args.parties, args.iters)
    if args.metrics:
        check_metrics(args.metrics, args.require_samples)


if __name__ == "__main__":
    main()
