#!/usr/bin/env python3
"""Validate efmvfl telemetry: trace JSONL directories and /metrics text.

Trace mode::

    check_trace.py TRACE_DIR --parties N [--iters N] [--require-wire]

Checks every ``party-*.jsonl`` file written by ``--trace-dir``:

- every line is a flat JSON object of scalars (the trace schema) with a
  string ``kind`` and an integer ``party`` matching the file name;
- the first thing each party logs is its ``clock`` anchor record
  (``epoch_unix_s``), which maps the party's monotonic timestamps onto
  the shared wall clock;
- span records carry ``stage``/``t``/``wall_s`` plus the HE counter
  fields (``ct_exps``, ``mont_sqrs``, ``mont_muls``, ``mont_work``);
- ``send``/``recv`` wire events carry the trace-context envelope fields
  (``tag``, ``t``, ``stage``, ``span_id``, ``seq``, ``bytes``,
  ``ts_s``) and each party's event timestamps are monotonic;
- **cross-party causality**: every ``recv`` links to a ``send`` in the
  sender's file with the same ``(from, to, seq)``, matching tag and
  ``span_id``, the linked span exists in the sender's file (span id 0
  means the frame left outside any open span), and after
  clock alignment no message arrives before it was sent (within
  ``--skew-tolerance`` seconds);
- ``clock_align`` records (per-peer ``offset_s``/``rtt_s`` from the
  control-plane ping exchange) are schema-checked; ``--require-wire``
  demands at least one send, one recv and one clock_align per party;
- for every iteration a party traced, all four pipeline stages appear,
  with at least one protocol round span (``stage == "proto"``);
- with ``--iters N``, the traced iterations are exactly ``0..N-1``.

Metrics mode::

    check_trace.py --metrics URL [--require-samples]

Scrapes the URL once and parses the body as Prometheus text exposition
(comment lines, or ``name[{labels}] value`` samples);
``--require-samples`` additionally demands at least one ``efmvfl_``
sample line.

Perfetto mode::

    check_trace.py --perfetto FILE

Validates a Chrome trace-event JSON file exported by
``report --perfetto`` (what ui.perfetto.dev opens): a ``traceEvents``
array of ``M``/``X``/``s``/``f`` events with sane pids/timestamps and
every flow-begin (``s``) paired with a flow-end (``f``).
"""

import argparse
import json
import re
import sys
import urllib.request

PIPELINE_STAGES = ["prepare", "mask_encrypt", "exchange", "combine"]
COUNTER_FIELDS = ["ct_exps", "mont_sqrs", "mont_muls", "mont_work"]
WIRE_FIELDS = ["tag", "t", "stage", "span_id", "seq", "bytes", "ts_s"]
SAMPLE_RE = re.compile(r"^[A-Za-z_:][A-Za-z0-9_:]*(\{[^{}]*\})?$")


def fail(msg):
    sys.exit(f"check_trace: FAIL: {msg}")


def check_record(where, rec):
    """Schema-check one parsed JSONL record; return (kind, party)."""
    if not isinstance(rec, dict):
        fail(f"{where}: record is not a JSON object")
    for key, value in rec.items():
        if isinstance(value, (dict, list)):
            fail(f"{where}: field {key!r} is not a scalar")
    kind = rec.get("kind")
    if not isinstance(kind, str) or not kind:
        fail(f"{where}: missing or non-string 'kind'")
    party = rec.get("party")
    if not isinstance(party, int) or party < 0:
        fail(f"{where}: missing or bad 'party'")
    if kind == "span":
        stage = rec.get("stage")
        if not isinstance(stage, str) or not stage:
            fail(f"{where}: span without a 'stage'")
        t = rec.get("t")
        if not isinstance(t, int) or t < 0:
            fail(f"{where}: span without an iteration 't'")
        wall = rec.get("wall_s")
        if not isinstance(wall, (int, float)) or wall < 0:
            fail(f"{where}: span without a non-negative 'wall_s'")
        for field in COUNTER_FIELDS:
            v = rec.get(field)
            if not isinstance(v, int) or v < 0:
                fail(f"{where}: span without counter {field!r}")
        if stage == "proto" and not isinstance(rec.get("proto"), str):
            fail(f"{where}: protocol span without a 'proto' tag")
    elif kind == "net":
        for field in ("from", "to", "bytes", "msgs"):
            v = rec.get(field)
            if not isinstance(v, int) or v < 0:
                fail(f"{where}: net event without {field!r}")
    elif kind in ("send", "recv"):
        peer = rec.get("to" if kind == "send" else "from")
        if not isinstance(peer, int) or peer < 0:
            fail(f"{where}: {kind} event without its peer party")
        for field in WIRE_FIELDS:
            v = rec.get(field)
            if field in ("tag", "stage"):
                if not isinstance(v, str) or not v:
                    fail(f"{where}: {kind} event without string {field!r}")
            elif field == "ts_s":
                if not isinstance(v, (int, float)) or v < 0:
                    fail(f"{where}: {kind} event without timestamp 'ts_s'")
            elif not isinstance(v, int) or v < 0:
                fail(f"{where}: {kind} event without {field!r}")
    elif kind == "clock":
        epoch = rec.get("epoch_unix_s")
        if not isinstance(epoch, (int, float)) or epoch <= 0:
            fail(f"{where}: clock record without 'epoch_unix_s'")
    elif kind == "clock_align":
        peer = rec.get("peer")
        if not isinstance(peer, int) or peer < 0:
            fail(f"{where}: clock_align without 'peer'")
        if not isinstance(rec.get("offset_s"), (int, float)):
            fail(f"{where}: clock_align without 'offset_s'")
        rtt = rec.get("rtt_s")
        if not isinstance(rtt, (int, float)) or rtt < 0:
            fail(f"{where}: clock_align without non-negative 'rtt_s'")
    return kind, party


def check_party_file(path, party):
    """Per-file checks; return this party's parsed view for linkage."""
    view = {
        "epoch": None,
        "span_ids": set(),
        "sends": {},   # (from, to, seq) -> send record
        "recvs": [],   # recv records (with file position for messages)
        "counts": {"send": 0, "recv": 0, "clock_align": 0},
        "stage_cover": set(),
        "proto_rounds": set(),
        "iterations": set(),
        "records": 0,
    }
    last_ts = 0.0
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        where = f"{path}:{lineno}"
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            fail(f"{where}: not JSON: {e}")
        kind, rec_party = check_record(where, rec)
        if rec_party != party:
            fail(f"{where}: party {rec_party} record in party {party}'s file")
        view["records"] += 1
        if kind == "clock":
            if view["epoch"] is None:
                view["epoch"] = rec["epoch_unix_s"]
        elif kind == "span":
            t = rec["t"]
            view["span_ids"].add(rec["span_id"])
            view["stage_cover"].add((rec["stage"], t))
            view["iterations"].add(t)
            if rec["stage"] == "proto":
                view["proto_rounds"].add(t)
        elif kind in ("send", "recv"):
            view["counts"][kind] += 1
            # a party's wire events are written in the order they happen
            # on its own monotonic clock
            if rec["ts_s"] < last_ts:
                fail(f"{where}: {kind} timestamp went backwards "
                     f"({rec['ts_s']} after {last_ts})")
            last_ts = rec["ts_s"]
            if kind == "send":
                key = (party, rec["to"], rec["seq"])
                if key in view["sends"]:
                    fail(f"{where}: duplicate send seq {rec['seq']} to "
                         f"party {rec['to']}")
                view["sends"][key] = rec
            else:
                view["recvs"].append((where, rec))
        elif kind == "clock_align":
            view["counts"]["clock_align"] += 1
    if view["epoch"] is None:
        fail(f"{path}: no clock anchor record (epoch_unix_s)")
    if not view["iterations"]:
        fail(f"{path}: no spans at all")
    return view


def check_linkage(views, skew_tolerance):
    """Cross-party pass: every recv pairs with its send, causally."""
    epochs = {p: v["epoch"] for p, v in views.items()}
    base = min(epochs.values())
    linked = 0
    for party, view in views.items():
        shift_recv = epochs[party] - base
        for where, rec in view["recvs"]:
            sender = rec["from"]
            if sender not in views:
                fail(f"{where}: recv from unknown party {sender}")
            key = (sender, party, rec["seq"])
            send = views[sender]["sends"].get(key)
            if send is None:
                fail(f"{where}: recv seq {rec['seq']} from party {sender} "
                     f"has no matching send in the sender's trace")
            if send["tag"] != rec["tag"]:
                fail(f"{where}: recv tag {rec['tag']!r} but the linked "
                     f"send carried {send['tag']!r}")
            if send["span_id"] != rec["span_id"]:
                fail(f"{where}: recv span_id {rec['span_id']} but the "
                     f"linked send carried {send['span_id']}")
            # span_id 0 = the frame left outside any open span (setup
            # traffic); anything else must name a span the sender logged
            if rec["span_id"] != 0 and rec["span_id"] not in views[sender]["span_ids"]:
                fail(f"{where}: linked span_id {rec['span_id']} never "
                     f"finished in party {sender}'s trace")
            sent_at = send["ts_s"] + (epochs[sender] - base)
            recv_at = rec["ts_s"] + shift_recv
            if recv_at + skew_tolerance < sent_at:
                fail(f"{where}: message received {sent_at - recv_at:.6f}s "
                     f"before it was sent (aligned clocks, tolerance "
                     f"{skew_tolerance}s)")
            linked += 1
    return linked


def check_trace_dir(trace_dir, parties, iters, require_wire, skew_tolerance):
    import pathlib

    root = pathlib.Path(trace_dir)
    views = {}
    for party in range(parties):
        path = root / f"party-{party}.jsonl"
        if not path.is_file():
            fail(f"missing trace file {path}")
        view = check_party_file(path, party)
        if iters is not None and view["iterations"] != set(range(iters)):
            fail(f"{path}: traced iterations {sorted(view['iterations'])}, "
                 f"expected 0..{iters - 1}")
        for t in sorted(view["iterations"]):
            for stage in PIPELINE_STAGES:
                if (stage, t) not in view["stage_cover"]:
                    fail(f"{path}: no {stage!r} span for iteration {t}")
            if t not in view["proto_rounds"]:
                fail(f"{path}: no protocol round span for iteration {t}")
        if require_wire:
            for kind in ("send", "recv", "clock_align"):
                if view["counts"][kind] == 0:
                    fail(f"{path}: --require-wire but no {kind} records")
        views[party] = view
    linked = check_linkage(views, skew_tolerance)
    records = sum(v["records"] for v in views.values())
    print(f"check_trace: OK: {records} records, {parties} parties, "
          f"all {len(PIPELINE_STAGES)} stages covered, "
          f"{linked} recv events causally linked")


def check_metrics(url, require_samples):
    with urllib.request.urlopen(url, timeout=10) as resp:
        body = resp.read().decode("utf-8")
    samples = 0
    for lineno, line in enumerate(body.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        parts = line.rsplit(None, 1)
        if len(parts) != 2:
            fail(f"metrics line {lineno}: not 'name value': {line!r}")
        name, value = parts
        if not SAMPLE_RE.match(name):
            fail(f"metrics line {lineno}: bad metric name {name!r}")
        try:
            float(value)
        except ValueError:
            fail(f"metrics line {lineno}: bad sample value {value!r}")
        samples += 1
    if require_samples and not any(
        l.startswith("efmvfl_") for l in body.splitlines()
    ):
        fail(f"no efmvfl_ samples scraped from {url}")
    print(f"check_trace: OK: {samples} Prometheus samples from {url}")


def check_perfetto(path):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: not readable JSON: {e}")
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        fail(f"{path}: no 'traceEvents' array (not Chrome trace-event JSON)")
    events = doc["traceEvents"]
    slices = 0
    flow_begin = set()
    flow_end = set()
    for i, ev in enumerate(events):
        where = f"{path}: traceEvents[{i}]"
        if not isinstance(ev, dict):
            fail(f"{where}: event is not an object")
        ph = ev.get("ph")
        if ph not in ("M", "X", "s", "f"):
            fail(f"{where}: unexpected phase {ph!r}")
        pid = ev.get("pid")
        if not isinstance(pid, int) or pid < 0:
            fail(f"{where}: missing or bad 'pid'")
        if ph == "M":
            if ev.get("name") != "process_name":
                fail(f"{where}: metadata event is not a process_name")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            fail(f"{where}: missing or negative 'ts'")
        if ph == "X":
            if not isinstance(ev.get("name"), str) or not ev["name"]:
                fail(f"{where}: slice without a 'name'")
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                fail(f"{where}: slice without non-negative 'dur'")
            slices += 1
        else:
            fid = ev.get("id")
            if not isinstance(fid, int) or fid < 0:
                fail(f"{where}: flow event without an integer 'id'")
            (flow_begin if ph == "s" else flow_end).add(fid)
            if ph == "f" and ev.get("bp") != "e":
                fail(f"{where}: flow end without bp='e' (Perfetto drops it)")
    if slices == 0:
        fail(f"{path}: no 'X' slices at all")
    if flow_begin != flow_end:
        odd = sorted(flow_begin ^ flow_end)[:5]
        fail(f"{path}: unpaired flow ids (e.g. {odd})")
    print(f"check_trace: OK: {path}: {slices} slices, "
          f"{len(flow_begin)} flow pairs, {len(events)} events")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace_dir", nargs="?", help="directory written by --trace-dir")
    ap.add_argument("--parties", type=int, default=3)
    ap.add_argument("--iters", type=int, help="require iterations 0..N-1 exactly")
    ap.add_argument("--require-wire", action="store_true",
                    help="demand send/recv/clock_align records per party")
    ap.add_argument("--skew-tolerance", type=float, default=0.02,
                    help="max allowed recv-before-send after clock "
                         "alignment, seconds (default 0.02)")
    ap.add_argument("--metrics", help="scrape and parse this /metrics URL")
    ap.add_argument("--require-samples", action="store_true",
                    help="with --metrics: demand at least one efmvfl_ sample")
    ap.add_argument("--perfetto", metavar="FILE",
                    help="validate a Chrome trace-event JSON export")
    args = ap.parse_args()
    if not args.trace_dir and not args.metrics and not args.perfetto:
        ap.error("give a TRACE_DIR, --metrics URL, --perfetto FILE, or several")
    if args.trace_dir:
        check_trace_dir(args.trace_dir, args.parties, args.iters,
                        args.require_wire, args.skew_tolerance)
    if args.metrics:
        check_metrics(args.metrics, args.require_samples)
    if args.perfetto:
        check_perfetto(args.perfetto)


if __name__ == "__main__":
    main()
