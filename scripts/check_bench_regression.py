#!/usr/bin/env python3
"""Fail CI when regenerated BENCH counters regress past the committed gates.

Each committed BENCH_*.json carries a ``ci_gates`` array of
``{"path": ..., "min": ..., "max": ...}`` entries emitted by the bench
itself.  The bounds are on the *fast-mode* (``EFMVFL_BENCH_FAST=1``)
deterministic counters — ct-exps, cipher bytes, modeled modexp work
ratios — with a small tolerance, so wall-clock noise never trips them
but giving back a packing/squaring/interleaving win does.  This script
resolves each dotted gate path (array indices as bare numbers, booleans
coerced to 1/0) in the regenerated report and exits non-zero listing
every violated bound, making the perf-trajectory job fail instead of
silently uploading a regressed artifact.

Usage: check_bench_regression.py <committed_dir> <regenerated_dir>
"""

import json
import sys

BENCH_FILES = ["BENCH_micro.json", "BENCH_p3.json", "BENCH_train.json"]


def resolve(doc, path):
    cur = doc
    for part in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(part)]
        elif isinstance(cur, dict):
            cur = cur[part]
        else:
            raise KeyError(path)
    return cur


def as_number(value):
    if isinstance(value, bool):
        return 1.0 if value else 0.0
    if isinstance(value, (int, float)):
        return float(value)
    raise TypeError("non-numeric value %r" % (value,))


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    committed_dir, regen_dir = sys.argv[1], sys.argv[2]
    failures = []
    checked = 0
    for name in BENCH_FILES:
        with open("%s/%s" % (committed_dir, name)) as fh:
            committed = json.load(fh)
        with open("%s/%s" % (regen_dir, name)) as fh:
            regen = json.load(fh)
        gates = committed.get("ci_gates", [])
        if not gates:
            failures.append("%s: committed file has no ci_gates" % name)
            continue
        for gate in gates:
            path = gate["path"]
            try:
                value = as_number(resolve(regen, path))
            except (KeyError, IndexError, ValueError, TypeError) as exc:
                failures.append("%s: %s: unresolvable (%s)" % (name, path, exc))
                continue
            checked += 1
            if "min" in gate and value < gate["min"]:
                failures.append(
                    "%s: %s = %s below min %s" % (name, path, value, gate["min"])
                )
            if "max" in gate and value > gate["max"]:
                failures.append(
                    "%s: %s = %s above max %s" % (name, path, value, gate["max"])
                )
    if failures:
        print("bench regression gate FAILED:")
        for msg in failures:
            print("  " + msg)
        sys.exit(1)
    print("bench regression gate OK: %d bounds hold" % checked)


if __name__ == "__main__":
    main()
